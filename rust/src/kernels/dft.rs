//! Discrete Fourier transform on the MMA facility — one of the two
//! "other research work" directions the paper's conclusion names ("their
//! use in stencil computations and discrete Fourier transform").
//!
//! A length-N DFT of a *batch* of real or complex signals is a matrix
//! multiplication by the N×N Fourier matrix — exactly the fine-grain
//! building-block use §III point 2 argues for ("the instructions of the
//! matrix math facility can be used as building blocks of other
//! computations, such as convolution, triangular solve and discrete
//! Fourier transform").
//!
//! A complex product `(Fr + i·Fi)·(xr + i·xi)` decomposes into four real
//! GEMMs, each executed here on the simulated `xvf64ger` datapath via
//! [`crate::kernels::dgemm::dgemm_sim`]; the host layer does the ±
//! combination (2 extra BLAS1 passes), just as an MMA-enabled FFT library
//! would.

use crate::isa::exec::ExecStats;
use crate::isa::ExecError;
use crate::kernels::dgemm::dgemm_sim;

/// The real/imaginary parts of the N×N DFT matrix
/// `F[j][k] = exp(-2πi·jk/N)`, row-major.
pub fn fourier_matrix(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut re = vec![0f64; n * n];
    let mut im = vec![0f64; n * n];
    for j in 0..n {
        for k in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64;
            re[j * n + k] = ang.cos();
            im[j * n + k] = ang.sin();
        }
    }
    (re, im)
}

/// The 16-point Fourier matrix split into `f32` real/imaginary parts
/// (`F[j][k] = exp(-2πi·jk/16)`, row-major) with **exact sqrt-derived
/// twiddles**: every entry is built from `sqrt(2)`, `sqrt(2±sqrt(2))/2`
/// and negations — operations IEEE 754 specifies as correctly rounded —
/// so any language computing the same formula produces bit-identical
/// values. This is the table the python AOT generator
/// (`python/compile/model.py`) embeds in the `dft_b32` fixture, which is
/// why the serving plan's pinned panels match the fixture constants bit
/// for bit with no libm `cos`/`sin` in the loop.
pub fn dft16_twiddles_f32() -> (Vec<f32>, Vec<f32>) {
    let s2 = 2f64.sqrt();
    let c1 = (2.0 + s2).sqrt() / 2.0; // cos(π/8)
    let c2 = s2 / 2.0; // cos(π/4)
    let c3 = (2.0 - s2).sqrt() / 2.0; // cos(3π/8)
    let cos = [1.0, c1, c2, c3, 0.0, -c3, -c2, -c1, -1.0, -c1, -c2, -c3, 0.0, c3, c2, c1];
    let sin = [0.0, c3, c2, c1, 1.0, c1, c2, c3, 0.0, -c3, -c2, -c1, -1.0, -c1, -c2, -c3];
    let mut fr = Vec::with_capacity(256);
    let mut fi = Vec::with_capacity(256);
    for j in 0..16 {
        for k in 0..16 {
            let t = (j * k) % 16;
            fr.push(cos[t] as f32);
            fi.push((-sin[t]) as f32);
        }
    }
    (fr, fi)
}

/// Batched complex DFT over the simulated MMA datapath.
///
/// `xr`/`xi` hold `batch` signals of length `n` **column-wise**: sample
/// `k` of signal `b` at `x[k*batch + b]` (so the GEMM is `F(n×n) ·
/// X(n×batch)`). Sizes off the Figure 6 kernel tile grid (multiples
/// of 8) are handled by zero-padding the GEMM panels — padded rows and
/// columns contribute only zero products, so the valid region of the
/// result is exactly the unpadded computation. Returns
/// `(yr, yi, stats)`.
pub fn dft_mma(
    xr: &[f64],
    xi: &[f64],
    n: usize,
    batch: usize,
) -> Result<(Vec<f64>, Vec<f64>, ExecStats), ExecError> {
    assert!(n > 0 && batch > 0, "empty DFT");
    assert_eq!(xr.len(), n * batch);
    assert_eq!(xi.len(), n * batch);
    let np = n.div_ceil(8) * 8;
    let bp = batch.div_ceil(8) * 8;
    let (fr, fi) = fourier_matrix(n);
    // zero-pad each row-major operand onto the tile grid (no-op copies
    // when already aligned)
    let pad = |src: &[f64], rows: usize, cols: usize, rp: usize, cp: usize| -> Vec<f64> {
        let mut p = vec![0f64; rp * cp];
        for r in 0..rows {
            p[r * cp..r * cp + cols].copy_from_slice(&src[r * cols..(r + 1) * cols]);
        }
        p
    };
    let frp = pad(&fr, n, n, np, np);
    let fip = pad(&fi, n, n, np, np);
    let xrp = pad(xr, n, batch, np, bp);
    let xip = pad(xi, n, batch, np, bp);
    // four real GEMMs on the MMA kernel
    let (rr, s1) = dgemm_sim(&frp, &xrp, np, bp, np)?;
    let (ii, s2) = dgemm_sim(&fip, &xip, np, bp, np)?;
    let (ri, s3) = dgemm_sim(&frp, &xip, np, bp, np)?;
    let (ir, s4) = dgemm_sim(&fip, &xrp, np, bp, np)?;
    let mut yrp = rr;
    let mut yip = ri;
    for (a, b) in yrp.iter_mut().zip(&ii) {
        *a -= b;
    }
    for (a, b) in yip.iter_mut().zip(&ir) {
        *a += b;
    }
    let unpad = |p: Vec<f64>| -> Vec<f64> {
        if np == n && bp == batch {
            return p;
        }
        let mut o = vec![0f64; n * batch];
        for j in 0..n {
            o[j * batch..(j + 1) * batch].copy_from_slice(&p[j * bp..j * bp + batch]);
        }
        o
    };
    let mut stats = s1;
    for s in [s2, s3, s4] {
        stats.instructions += s.instructions;
        stats.mma_instructions += s.mma_instructions;
        stats.flops += s.flops;
        stats.loads += s.loads;
        stats.stores += s.stores;
        stats.mem_bytes += s.mem_bytes;
    }
    Ok((unpad(yrp), unpad(yip), stats))
}

/// Scalar reference DFT (O(N²), exact summation order independent).
pub fn dft_reference(xr: &[f64], xi: &[f64], n: usize, batch: usize) -> (Vec<f64>, Vec<f64>) {
    let mut yr = vec![0f64; n * batch];
    let mut yi = vec![0f64; n * batch];
    for b in 0..batch {
        for j in 0..n {
            let (mut sr, mut si) = (0f64, 0f64);
            for k in 0..n {
                let ang = -2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64;
                let (c, s) = (ang.cos(), ang.sin());
                let (re, im) = (xr[k * batch + b], xi[k * batch + b]);
                sr += c * re - s * im;
                si += c * im + s * re;
            }
            yr[j * batch + b] = sr;
            yi[j * batch + b] = si;
        }
    }
    (yr, yi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_allclose, Rng};

    #[test]
    fn fourier_matrix_first_row_is_ones() {
        let (re, im) = fourier_matrix(16);
        for k in 0..16 {
            assert!((re[k] - 1.0).abs() < 1e-12);
            assert!(im[k].abs() < 1e-12);
        }
    }

    #[test]
    fn dft_of_impulse_is_flat() {
        // x = delta at sample 0 -> X[j] = 1 for all j
        let n = 16;
        let batch = 8;
        let mut xr = vec![0f64; n * batch];
        for b in 0..batch {
            xr[b] = 1.0; // sample 0 of each signal
        }
        let xi = vec![0f64; n * batch];
        let (yr, yi, stats) = dft_mma(&xr, &xi, n, batch).unwrap();
        for j in 0..n {
            for b in 0..batch {
                assert!((yr[j * batch + b] - 1.0).abs() < 1e-12);
                assert!(yi[j * batch + b].abs() < 1e-12);
            }
        }
        assert!(stats.mma_instructions > 0, "ran on the simulated MME");
    }

    #[test]
    fn dft_of_pure_tone_is_a_spike() {
        let n = 32;
        let batch = 8;
        let freq = 5;
        let mut xr = vec![0f64; n * batch];
        let mut xi = vec![0f64; n * batch];
        for k in 0..n {
            let ang = 2.0 * std::f64::consts::PI * (freq * k % n) as f64 / n as f64;
            xr[k * batch] = ang.cos();
            xi[k * batch] = ang.sin();
        }
        let (yr, yi, _) = dft_mma(&xr, &xi, n, batch).unwrap();
        for j in 0..n {
            let mag = (yr[j * batch].powi(2) + yi[j * batch].powi(2)).sqrt();
            if j == freq {
                assert!((mag - n as f64).abs() < 1e-9, "bin {j}: {mag}");
            } else {
                assert!(mag < 1e-9, "bin {j} leaked {mag}");
            }
        }
    }

    #[test]
    fn dft_matches_reference_random() {
        let mut rng = Rng::new(77);
        let n = 24;
        let batch = 8;
        let xr = rng.f64_vec(n * batch);
        let xi = rng.f64_vec(n * batch);
        let (yr, yi, _) = dft_mma(&xr, &xi, n, batch).unwrap();
        let (er, ei) = dft_reference(&xr, &xi, n, batch);
        assert_allclose(&yr, &er, 1e-10, 1e-10);
        assert_allclose(&yi, &ei, 1e-10, 1e-10);
    }

    #[test]
    fn dft_off_tile_sizes_pad_transparently() {
        // n and batch deliberately NOT multiples of 8: the zero-padded
        // panels must reproduce the unpadded reference exactly
        let mut rng = Rng::new(41);
        for (n, batch) in [(12, 5), (7, 3), (16, 9), (13, 8)] {
            let xr = rng.f64_vec(n * batch);
            let xi = rng.f64_vec(n * batch);
            let (yr, yi, _) = dft_mma(&xr, &xi, n, batch).unwrap();
            let (er, ei) = dft_reference(&xr, &xi, n, batch);
            assert_allclose(&yr, &er, 1e-10, 1e-10);
            assert_allclose(&yi, &ei, 1e-10, 1e-10);
        }
    }

    #[test]
    fn exact_twiddles_match_libm_fourier_matrix() {
        let (fr, fi) = dft16_twiddles_f32();
        let (er, ei) = fourier_matrix(16);
        for idx in 0..256 {
            assert!((fr[idx] as f64 - er[idx]).abs() < 1e-7, "re[{idx}]");
            assert!((fi[idx] as f64 - ei[idx]).abs() < 1e-7, "im[{idx}]");
        }
        // the sqrt table is symmetric like the matrix itself
        for j in 0..16 {
            for k in 0..16 {
                assert_eq!(fr[j * 16 + k].to_bits(), fr[k * 16 + j].to_bits());
                assert_eq!(fi[j * 16 + k].to_bits(), fi[k * 16 + j].to_bits());
            }
        }
    }

    #[test]
    fn parseval_energy_conservation() {
        let mut rng = Rng::new(3);
        let n = 16;
        let batch = 8;
        let xr = rng.f64_vec(n * batch);
        let xi = rng.f64_vec(n * batch);
        let (yr, yi, _) = dft_mma(&xr, &xi, n, batch).unwrap();
        for b in 0..batch {
            let ein: f64 = (0..n)
                .map(|k| xr[k * batch + b].powi(2) + xi[k * batch + b].powi(2))
                .sum();
            let eout: f64 = (0..n)
                .map(|j| yr[j * batch + b].powi(2) + yi[j * batch + b].powi(2))
                .sum();
            assert!((eout - n as f64 * ein).abs() < 1e-8 * eout.abs().max(1.0), "signal {b}");
        }
    }
}
