//! Discrete Fourier transform on the MMA facility — one of the two
//! "other research work" directions the paper's conclusion names ("their
//! use in stencil computations and discrete Fourier transform").
//!
//! A length-N DFT of a *batch* of real or complex signals is a matrix
//! multiplication by the N×N Fourier matrix — exactly the fine-grain
//! building-block use §III point 2 argues for ("the instructions of the
//! matrix math facility can be used as building blocks of other
//! computations, such as convolution, triangular solve and discrete
//! Fourier transform").
//!
//! A complex product `(Fr + i·Fi)·(xr + i·xi)` decomposes into four real
//! GEMMs, each executed here on the simulated `xvf64ger` datapath via
//! [`crate::kernels::dgemm::dgemm_sim`]; the host layer does the ±
//! combination (2 extra BLAS1 passes), just as an MMA-enabled FFT library
//! would.

use crate::isa::exec::ExecStats;
use crate::isa::ExecError;
use crate::kernels::dgemm::dgemm_sim;

/// The real/imaginary parts of the N×N DFT matrix
/// `F[j][k] = exp(-2πi·jk/N)`, row-major.
pub fn fourier_matrix(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut re = vec![0f64; n * n];
    let mut im = vec![0f64; n * n];
    for j in 0..n {
        for k in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64;
            re[j * n + k] = ang.cos();
            im[j * n + k] = ang.sin();
        }
    }
    (re, im)
}

/// Batched complex DFT over the simulated MMA datapath.
///
/// `xr`/`xi` hold `batch` signals of length `n` **column-wise**: sample
/// `k` of signal `b` at `x[k*batch + b]` (so the GEMM is `F(n×n) ·
/// X(n×batch)`). `n` must be a multiple of 8 and `batch` a multiple of 8
/// (the Figure 6 kernel tile); returns `(yr, yi, stats)`.
pub fn dft_mma(
    xr: &[f64],
    xi: &[f64],
    n: usize,
    batch: usize,
) -> Result<(Vec<f64>, Vec<f64>, ExecStats), ExecError> {
    assert!(n % 8 == 0 && batch % 8 == 0, "tile-multiple sizes (pad otherwise)");
    assert_eq!(xr.len(), n * batch);
    assert_eq!(xi.len(), n * batch);
    let (fr, fi) = fourier_matrix(n);
    // four real GEMMs on the MMA kernel
    let (rr, s1) = dgemm_sim(&fr, xr, n, batch, n)?;
    let (ii, s2) = dgemm_sim(&fi, xi, n, batch, n)?;
    let (ri, s3) = dgemm_sim(&fr, xi, n, batch, n)?;
    let (ir, s4) = dgemm_sim(&fi, xr, n, batch, n)?;
    let mut yr = rr;
    let mut yi = ri;
    for (a, b) in yr.iter_mut().zip(&ii) {
        *a -= b;
    }
    for (a, b) in yi.iter_mut().zip(&ir) {
        *a += b;
    }
    let mut stats = s1;
    for s in [s2, s3, s4] {
        stats.instructions += s.instructions;
        stats.mma_instructions += s.mma_instructions;
        stats.flops += s.flops;
        stats.loads += s.loads;
        stats.stores += s.stores;
        stats.mem_bytes += s.mem_bytes;
    }
    Ok((yr, yi, stats))
}

/// Scalar reference DFT (O(N²), exact summation order independent).
pub fn dft_reference(xr: &[f64], xi: &[f64], n: usize, batch: usize) -> (Vec<f64>, Vec<f64>) {
    let mut yr = vec![0f64; n * batch];
    let mut yi = vec![0f64; n * batch];
    for b in 0..batch {
        for j in 0..n {
            let (mut sr, mut si) = (0f64, 0f64);
            for k in 0..n {
                let ang = -2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64;
                let (c, s) = (ang.cos(), ang.sin());
                let (re, im) = (xr[k * batch + b], xi[k * batch + b]);
                sr += c * re - s * im;
                si += c * im + s * re;
            }
            yr[j * batch + b] = sr;
            yi[j * batch + b] = si;
        }
    }
    (yr, yi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_allclose, Rng};

    #[test]
    fn fourier_matrix_first_row_is_ones() {
        let (re, im) = fourier_matrix(16);
        for k in 0..16 {
            assert!((re[k] - 1.0).abs() < 1e-12);
            assert!(im[k].abs() < 1e-12);
        }
    }

    #[test]
    fn dft_of_impulse_is_flat() {
        // x = delta at sample 0 -> X[j] = 1 for all j
        let n = 16;
        let batch = 8;
        let mut xr = vec![0f64; n * batch];
        for b in 0..batch {
            xr[b] = 1.0; // sample 0 of each signal
        }
        let xi = vec![0f64; n * batch];
        let (yr, yi, stats) = dft_mma(&xr, &xi, n, batch).unwrap();
        for j in 0..n {
            for b in 0..batch {
                assert!((yr[j * batch + b] - 1.0).abs() < 1e-12);
                assert!(yi[j * batch + b].abs() < 1e-12);
            }
        }
        assert!(stats.mma_instructions > 0, "ran on the simulated MME");
    }

    #[test]
    fn dft_of_pure_tone_is_a_spike() {
        let n = 32;
        let batch = 8;
        let freq = 5;
        let mut xr = vec![0f64; n * batch];
        let mut xi = vec![0f64; n * batch];
        for k in 0..n {
            let ang = 2.0 * std::f64::consts::PI * (freq * k % n) as f64 / n as f64;
            xr[k * batch] = ang.cos();
            xi[k * batch] = ang.sin();
        }
        let (yr, yi, _) = dft_mma(&xr, &xi, n, batch).unwrap();
        for j in 0..n {
            let mag = (yr[j * batch].powi(2) + yi[j * batch].powi(2)).sqrt();
            if j == freq {
                assert!((mag - n as f64).abs() < 1e-9, "bin {j}: {mag}");
            } else {
                assert!(mag < 1e-9, "bin {j} leaked {mag}");
            }
        }
    }

    #[test]
    fn dft_matches_reference_random() {
        let mut rng = Rng::new(77);
        let n = 24;
        let batch = 8;
        let xr = rng.f64_vec(n * batch);
        let xi = rng.f64_vec(n * batch);
        let (yr, yi, _) = dft_mma(&xr, &xi, n, batch).unwrap();
        let (er, ei) = dft_reference(&xr, &xi, n, batch);
        assert_allclose(&yr, &er, 1e-10, 1e-10);
        assert_allclose(&yi, &ei, 1e-10, 1e-10);
    }

    #[test]
    fn parseval_energy_conservation() {
        let mut rng = Rng::new(3);
        let n = 16;
        let batch = 8;
        let xr = rng.f64_vec(n * batch);
        let xi = rng.f64_vec(n * batch);
        let (yr, yi, _) = dft_mma(&xr, &xi, n, batch).unwrap();
        for b in 0..batch {
            let ein: f64 = (0..n)
                .map(|k| xr[k * batch + b].powi(2) + xi[k * batch + b].powi(2))
                .sum();
            let eout: f64 = (0..n)
                .map(|j| yr[j * batch + b].powi(2) + yi[j * batch + b].powi(2))
                .sum();
            assert!((eout - n as f64 * ein).abs() < 1e-8 * eout.abs().max(1.0), "signal {b}");
        }
    }
}
