//! POWER9-compliant vector (VSX) kernels — the baseline code of the
//! paper's §VI measurements ("a POWER9-compliant code that only uses
//! POWER9 ISA instructions (vector instructions)").
//!
//! The DGEMM micro-kernel keeps an `8×4` fp64 C block in 16 VSRs (2 columns
//! per register). Each k iteration loads one column of A (4 `lxv`) and one
//! row of B (2 `lxv`), **splats** every A element to both vector lanes
//! (8 `xxspltd` — the §III comparison point: "processors with vector
//! instructions require additional steps … broadcast loads or splat
//! instructions"), then performs 16 `xvmaddadp`.
//!
//! Per iteration: 64 flops from 16 FMA + 8 splat = 24 VSU ops. On two
//! VSU pipes (POWER9) that is ≥12 cycles → ≤5.3 flops/cycle of the 8-peak,
//! matching the ~56% efficiency of Figure 11; on four pipes (POWER10-VSX)
//! ≤10.7 of the 16-peak (~62% measured).

use crate::isa::inst::Inst;
use crate::isa::{ExecError, Machine};

/// Register map (all in the never-conflicting vs32..vs63 range):
/// C block: vs32..vs47 (c[row][colpair] = vs32 + 2*row + colpair)
/// A column: vs48..vs51 (row pairs), splats: vs52..vs59, B row: vs60..vs61.
const C0: u8 = 32;
const A0: u8 = 48;
const S0: u8 = 52;
const B0: u8 = 60;

/// Generate the VSX `8×k×4` DGEMM kernel.
///
/// Calling convention: `r3` = output C (8×4 row-major, 256 B), `r4` =
/// packed A panel (8 fp64 per column, 64 B/column), `r5` = packed B panel
/// (4 fp64 per row, 32 B/row).
pub fn vsx_dgemm_8x4_program(k: usize) -> Vec<Inst> {
    assert!(k >= 1);
    assert!(k <= i16::MAX as usize);
    let mut p = Vec::new();
    // zero the C block (the xxlxor idiom)
    for r in 0..16u8 {
        let c = C0 + r;
        p.push(Inst::Xxlxor { xt: c, xa: c, xb: c });
    }
    p.push(Inst::Addi { rt: 9, ra: 0, si: k as i32 });
    p.push(Inst::Mtctr { rs: 9 });
    let mut body = Vec::new();
    // loads: A column (8 f64 = 4 lxv), B row (4 f64 = 2 lxv)
    for i in 0..4u8 {
        body.push(Inst::Lxv { xt: A0 + i, ra: 4, dq: 16 * i32::from(i) });
    }
    body.push(Inst::Lxv { xt: B0, ra: 5, dq: 0 });
    body.push(Inst::Lxv { xt: B0 + 1, ra: 5, dq: 16 });
    body.push(Inst::Addi { rt: 4, ra: 4, si: 64 });
    body.push(Inst::Addi { rt: 5, ra: 5, si: 32 });
    // splat each A element: row i lives in vs(A0 + i/2) lane i%2
    for i in 0..8u8 {
        body.push(Inst::XxSpltd { xt: S0 + i, xa: A0 + i / 2, h: i % 2 });
    }
    // 16 FMAs: c[i][jc] += splat_a[i] * b[jc]
    for i in 0..8u8 {
        for jc in 0..2u8 {
            body.push(Inst::XvMaddaDp { xt: C0 + 2 * i + jc, xa: S0 + i, xb: B0 + jc });
        }
    }
    let body_bytes = 4 * (body.len() + 1) as i32;
    p.extend(body);
    p.push(Inst::Bdnz { bd: -(body_bytes - 4) });
    // epilogue: store C (row i at r3 + 32*i)
    for i in 0..8u8 {
        for jc in 0..2u8 {
            p.push(Inst::Stxv { xs: C0 + 2 * i + jc, ra: 3, dq: 32 * i32::from(i) + 16 * i32::from(jc) });
        }
    }
    p.push(Inst::Blr);
    p
}

/// Dynamic instruction count of one kernel call (for the trace cache).
pub fn vsx_dgemm_8x4_dynamic_insts(k: usize) -> u64 {
    // 18 prologue + (32-instruction body + bdnz) per iteration + 17 epilogue
    18 + 33 * k as u64 + 17
}

/// Execute the VSX kernel: `a` is a packed 8×k panel (column-major),
/// `b` a packed 4×k panel (row `kk` = 4 f64 at `32·kk`). Returns the
/// row-major 8×4 block `C[i][j] = Σ_k a[i,k]·b[j,k]`.
pub fn run_vsx_dgemm_8x4(a: &[f64], b: &[f64], k: usize) -> Result<[[f64; 4]; 8], ExecError> {
    assert_eq!(a.len(), 8 * k);
    assert_eq!(b.len(), 4 * k);
    let ab = 0u64;
    let bb = (8 * k * 8) as u64;
    let cb = bb + (4 * k * 8) as u64;
    let mut m = Machine::new(cb as usize + 256);
    m.write_f64s(ab, a);
    m.write_f64s(bb, b);
    m.gpr[3] = cb;
    m.gpr[4] = ab;
    m.gpr[5] = bb;
    let prog = vsx_dgemm_8x4_program(k);
    m.run(&prog, 64 + 40 * k as u64)?;
    let raw = m.read_f64s(cb, 32);
    let mut c = [[0f64; 4]; 8];
    for i in 0..8 {
        for j in 0..4 {
            c[i][j] = raw[4 * i + j];
        }
    }
    Ok(c)
}

/// Per-iteration instruction mix of the VSX kernel, used by the §III
/// comparison bench (operand traffic: vector code must also write C back
/// through the register file, unlike the MME-resident accumulators).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VsxLoopProfile {
    pub loads: u32,
    pub splats: u32,
    pub fmas: u32,
    pub bookkeeping: u32,
    pub flops: u32,
}

/// The per-iteration profile of [`vsx_dgemm_8x4_program`].
pub const VSX_8X4_PROFILE: VsxLoopProfile =
    VsxLoopProfile { loads: 6, splats: 8, fmas: 16, bookkeeping: 3, flops: 64 };

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    #[test]
    fn vsx_kernel_vs_naive() {
        check("vsx dgemm 8x4", 20, |rng: &mut Rng| {
            let k = rng.range(1, 40);
            let a = rng.f64_vec(8 * k);
            let b = rng.f64_vec(4 * k);
            let c = run_vsx_dgemm_8x4(&a, &b, k).unwrap();
            for i in 0..8 {
                for j in 0..4 {
                    let e: f64 = (0..k).map(|kk| a[kk * 8 + i] * b[kk * 4 + j]).sum();
                    assert!((c[i][j] - e).abs() <= 1e-12 * e.abs().max(1.0), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn instruction_mix_matches_profile() {
        let prog = vsx_dgemm_8x4_program(5);
        let splats = prog.iter().filter(|i| matches!(i, Inst::XxSpltd { .. })).count();
        let fmas = prog.iter().filter(|i| matches!(i, Inst::XvMaddaDp { .. })).count();
        let loads = prog.iter().filter(|i| matches!(i, Inst::Lxv { .. })).count();
        // static counts: one loop body
        assert_eq!(splats, VSX_8X4_PROFILE.splats as usize);
        assert_eq!(fmas, VSX_8X4_PROFILE.fmas as usize);
        assert_eq!(loads, VSX_8X4_PROFILE.loads as usize);
    }

    #[test]
    fn dynamic_instruction_count() {
        for k in [1usize, 2, 7, 31] {
            let a = vec![1.0; 8 * k];
            let b = vec![1.0; 4 * k];
            let ab = 0u64;
            let bb = (8 * k * 8) as u64;
            let cb = bb + (4 * k * 8) as u64;
            let mut m = Machine::new(cb as usize + 256);
            m.write_f64s(ab, &a);
            m.write_f64s(bb, &b);
            m.gpr[3] = cb;
            m.gpr[4] = ab;
            m.gpr[5] = bb;
            m.run(&vsx_dgemm_8x4_program(k), 1 << 20).unwrap();
            assert_eq!(m.stats.instructions, vsx_dgemm_8x4_dynamic_insts(k), "k={k}");
        }
    }

    #[test]
    fn mma_advantage_no_splats() {
        // §III point 4: the MMA kernel needs no splat/broadcast instructions
        let mma = crate::kernels::dgemm::dgemm_8xnx8_program(16);
        assert_eq!(mma.iter().filter(|i| matches!(i, Inst::XxSpltd { .. })).count(), 0);
        // and per-flop it issues fewer instructions than the VSX kernel
        let mma_flops_per_inst = (16.0 * 8.0 * 8.0 * 2.0) / 17.0 / 16.0; // loop: 128 flops / 17 insts
        let vsx_flops_per_inst = 64.0 / 31.0;
        assert!(mma_flops_per_inst * 16.0 > vsx_flops_per_inst * 2.0);
    }
}

// ---------------------------------------------------------------------------
// fp32 VSX baseline (the POWER9 code path for the §VI ResNet-50 comparison)
// ---------------------------------------------------------------------------

/// fp32 register map: C 8×8 block in vs32..vs47 (row i, col-quad jc at
/// vs32+2i+jc), A column vs48..49, splats vs52..59, B row vs60..61.
///
/// Generate the VSX `8×k×8` SGEMM kernel: per iteration 2+2 `lxv`,
/// 8 `xxspltw`, 16 `xvmaddasp` (128 flops — 24 VSU ops, the same
/// splat-bound structure as the fp64 kernel).
pub fn vsx_sgemm_8x8_program(k: usize) -> Vec<Inst> {
    assert!(k >= 1 && k <= i16::MAX as usize);
    let mut p = Vec::new();
    for r in 0..16u8 {
        let c = C0 + r;
        p.push(Inst::Xxlxor { xt: c, xa: c, xb: c });
    }
    p.push(Inst::Addi { rt: 9, ra: 0, si: k as i32 });
    p.push(Inst::Mtctr { rs: 9 });
    let mut body = Vec::new();
    // A column: 8 f32 = 2 lxv; B row: 8 f32 = 2 lxv
    body.push(Inst::Lxv { xt: A0, ra: 4, dq: 0 });
    body.push(Inst::Lxv { xt: A0 + 1, ra: 4, dq: 16 });
    body.push(Inst::Lxv { xt: B0, ra: 5, dq: 0 });
    body.push(Inst::Lxv { xt: B0 + 1, ra: 5, dq: 16 });
    body.push(Inst::Addi { rt: 4, ra: 4, si: 32 });
    body.push(Inst::Addi { rt: 5, ra: 5, si: 32 });
    // splat each of the 8 A elements (word w of vs48/49)
    for i in 0..8u8 {
        body.push(Inst::XxSpltw { xt: S0 + i, xa: A0 + i / 4, w: i % 4 });
    }
    // c[i][jc] += splat_a[i] * b[jc]
    for i in 0..8u8 {
        for jc in 0..2u8 {
            body.push(Inst::XvMaddaSp { xt: C0 + 2 * i + jc, xa: S0 + i, xb: B0 + jc });
        }
    }
    let body_bytes = 4 * body.len() as i32;
    p.extend(body);
    p.push(Inst::Bdnz { bd: -body_bytes });
    for i in 0..8u8 {
        for jc in 0..2u8 {
            p.push(Inst::Stxv { xs: C0 + 2 * i + jc, ra: 3, dq: 32 * i32::from(i) + 16 * i32::from(jc) });
        }
    }
    p.push(Inst::Blr);
    p
}

/// Execute the fp32 VSX kernel: `a` packed 8×k (column-major), `b` packed
/// 8×k (row kk = 8 f32 at 32·kk bytes). Returns `C[i][j] = Σ a[i,k]·b[j,k]`.
pub fn run_vsx_sgemm_8x8(a: &[f32], b: &[f32], k: usize) -> Result<[[f32; 8]; 8], ExecError> {
    assert_eq!(a.len(), 8 * k);
    assert_eq!(b.len(), 8 * k);
    let ab = 0u64;
    let bb = (8 * k * 4).next_multiple_of(16) as u64;
    let cb = bb + (8 * k * 4).next_multiple_of(16) as u64;
    let mut m = Machine::new(cb as usize + 256);
    m.write_f32s(ab, a);
    m.write_f32s(bb, b);
    m.gpr[3] = cb;
    m.gpr[4] = ab;
    m.gpr[5] = bb;
    m.run(&vsx_sgemm_8x8_program(k), 64 + 40 * k as u64)?;
    let raw = m.read_f32s(cb, 64);
    let mut c = [[0f32; 8]; 8];
    for i in 0..8 {
        for j in 0..8 {
            c[i][j] = raw[8 * i + j];
        }
    }
    Ok(c)
}

#[cfg(test)]
mod sgemm_tests {
    use super::*;
    use crate::testkit::{check, Rng};

    #[test]
    fn vsx_sgemm_vs_naive() {
        check("vsx sgemm 8x8", 15, |rng: &mut Rng| {
            let k = rng.range(1, 30);
            let a = rng.f32_vec(8 * k);
            let b = rng.f32_vec(8 * k);
            let c = run_vsx_sgemm_8x8(&a, &b, k).unwrap();
            for i in 0..8 {
                for j in 0..8 {
                    let e: f32 = (0..k).map(|kk| a[kk * 8 + i] * b[kk * 8 + j]).sum();
                    assert!((c[i][j] - e).abs() <= 1e-4 * e.abs().max(1.0), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn sgemm_flop_rate_doubles_dgemm() {
        // fp32 lanes double the per-iteration flops of the fp64 kernel
        let prog = vsx_sgemm_8x8_program(4);
        let fmas = prog.iter().filter(|i| matches!(i, Inst::XvMaddaSp { .. })).count();
        assert_eq!(fmas, 16);
        let flops_per_iter: u64 =
            prog.iter().filter(|i| matches!(i, Inst::XvMaddaSp { .. })).map(|i| i.flops()).sum();
        assert_eq!(flops_per_iter, 16 * 8);
    }
}
