//! The hand-written MMA kernel library (paper §V–§VI) plus the
//! POWER9-compliant VSX baselines the evaluation compares against.
//!
//! Every kernel is generated as a real instruction stream through the
//! [`crate::builtins`] layer (the paper's recommended programming model) and
//! runs on the functional [`crate::isa::Machine`]; the cycle model times the
//! very same streams.
//!
//! * [`dgemm`] — the §V-A DGEMM `8×N×8` kernel (Figures 5–7) and the
//!   blocked `128×128×128` kernel of §VI, plus host-side packing.
//! * [`sconv`] — the §V-B SCONV `8×27×16` 2-D convolution kernel
//!   (Figures 8–9).
//! * [`gemm_rp`] — reduced-precision GEMM kernels: fp32, bf16/fp16
//!   (rank-2), int16, int8 — the "OpenBLAS enablement" of §VIII.
//! * [`vsx`] — POWER9-compliant vector kernels (the baseline code of §VI's
//!   measurements: splat + `xvmaddadp`).
//! * [`pack`] — panel packing/unpacking shared by the host runners.

pub mod dft;
pub mod dgemm;
pub mod gemm_rp;
pub mod stencil;
pub mod pack;
pub mod sconv;
pub mod vsx;
