//! Panel packing and unpacking for the kernel host runners.
//!
//! The paper's kernels consume *packed* operands (as any high-performance
//! GEMM does — "that is handled in other layers of DGEMM", §V-A):
//!
//! * an `8×K` **X panel**: column `k` stored as 8 consecutive elements at
//!   `base + k*8*sizeof(T)` (what `lxvp`+`lxvp` load per iteration);
//! * an `8×K` **Y panel**: identical layout (4 `lxv` per iteration);
//! * the `8×8` **accumulator image**: eight 4×2 accumulator blocks in the
//!   Figure 4/6 order — block `s` covers rows `4*(s/4)..` and columns
//!   `2*(s%4)..`, stored row-by-row, 16 bytes per row.

/// Pack an `8×k` row-major matrix (`a[i*lda + j]`, 8 rows) into the
/// column-panel layout (column-major 8-row panel).
pub fn pack_panel_f64(a: &[f64], lda: usize, k: usize) -> Vec<f64> {
    let mut out = vec![0f64; 8 * k];
    for kk in 0..k {
        for i in 0..8 {
            out[kk * 8 + i] = a[i * lda + kk];
        }
    }
    out
}

/// Pack an A micropanel for the blocked f32 GEMM (`blas::block_gemm`):
/// rows `i0 .. i0+rows` × columns `k0 .. k0+kc` of a row-major `a` with
/// row stride `lda`, transposed into the column-panel layout the paper's
/// kernels consume — column `p` stored as `mr` consecutive elements at
/// `out[p*mr ..]` (`out[p*mr + i] = a[(i0+i)*lda + k0+p]`). Rows past
/// `rows` (the m-tail of a partial panel) are zero-filled so the
/// microkernel never branches; `out` must hold `kc*mr` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_panel_f32(
    a: &[f32],
    lda: usize,
    i0: usize,
    rows: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    out: &mut [f32],
) {
    debug_assert!(rows <= mr && out.len() >= kc * mr);
    for p in 0..kc {
        let col = &mut out[p * mr..(p + 1) * mr];
        for (i, slot) in col.iter_mut().enumerate() {
            *slot = if i < rows { a[(i0 + i) * lda + k0 + p] } else { 0.0 };
        }
    }
}

/// Precompiled im2col gather: a `K×N` *virtual* B matrix over a padded
/// `[Cin, IH, IW]` image, never materialized. Row `k` of the virtual
/// matrix is one shifted image window — tap `k` of a 3×3 convolution
/// recast as a matrix multiply (the paper's Figure 9 SCONV shape):
///
/// ```text
/// B[k, col] = img[bases[k] + (col / out_w) * img_w + (col % out_w)]
/// ```
///
/// where `bases[k] = c·IH·IW + dy·IW + dx` encodes the tap's channel and
/// spatial offset, `img_w` is the padded image row stride (`IW`), and
/// `out_w` is the output width (`col` enumerates output pixels row-major
/// over `H×W`, so `N = H·W`). Built once at plan-compile time by the
/// conv rewrite pass ([`crate::runtime::plan`]); consumed per request by
/// [`pack_b_im2col_f32`], which packs the windows **directly** into the
/// [`pack_b_panel_f32`] panel layout the blocked GEMM microkernel reads —
/// the im2col matrix itself never touches memory.
#[derive(Clone, Debug)]
pub struct Im2colSpec {
    /// Per-`k` flat base offset into the image (`c·IH·IW + dy·IW + dx`).
    pub bases: Vec<usize>,
    /// Row stride of the padded image (`IW`).
    pub img_w: usize,
    /// Output width (`W`): columns per output row of the gather.
    pub out_w: usize,
}

/// Pack a B micropanel of the *virtual* im2col matrix described by
/// `spec` (see [`Im2colSpec`]): rows `k0 .. k0+kc` × columns
/// `j0 .. j0+cols`, gathered straight from the padded image into the
/// same layout as [`pack_b_panel_f32`] — row `p` stored as `nr`
/// consecutive elements at `out[p*nr ..]`, columns past `cols` (the
/// n-tail) zero-filled. `out` must hold `kc*nr` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_b_im2col_f32(
    img: &[f32],
    spec: &Im2colSpec,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [f32],
) {
    debug_assert!(cols <= nr && out.len() >= kc * nr);
    // (y, x) of the first packed column, advanced incrementally per
    // column (consecutive cols walk the output row-major) — the inner
    // loop then performs no div/mod
    let (y0, x0) = (j0 / spec.out_w, j0 % spec.out_w);
    for p in 0..kc {
        let base = spec.bases[k0 + p];
        let row = &mut out[p * nr..(p + 1) * nr];
        let (mut y, mut x) = (y0, x0);
        for slot in row[..cols].iter_mut() {
            *slot = img[base + y * spec.img_w + x];
            x += 1;
            if x == spec.out_w {
                x = 0;
                y += 1;
            }
        }
        row[cols..].fill(0.0);
    }
}

/// Pack a B micropanel for the blocked f32 GEMM: rows `k0 .. k0+kc` ×
/// columns `j0 .. j0+cols` of a row-major `b` with row stride `ldb`, kept
/// row-major per step — row `p` stored as `nr` consecutive elements at
/// `out[p*nr ..]` (`out[p*nr + j] = b[(k0+p)*ldb + j0+j]`). Columns past
/// `cols` (the n-tail) are zero-filled; `out` must hold `kc*nr` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_b_panel_f32(
    b: &[f32],
    ldb: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [f32],
) {
    debug_assert!(cols <= nr && out.len() >= kc * nr);
    for p in 0..kc {
        let row = &mut out[p * nr..(p + 1) * nr];
        let src = &b[(k0 + p) * ldb + j0..];
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = if j < cols { src[j] } else { 0.0 };
        }
    }
}

/// Unpack the DGEMM result written by the Figure 6 epilogue into a row-major
/// `8×8` matrix.
///
/// Block `s` (`s = 0..8`) holds rows `4*(s/4) .. 4*(s/4)+4` × columns
/// `2*(s%4) .. 2*(s%4)+2`; each block row is 2 f64 (16 bytes).
pub fn unpack_c8x8_f64(raw: &[f64]) -> [[f64; 8]; 8] {
    assert_eq!(raw.len(), 64);
    let mut c = [[0f64; 8]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 2 * (s % 4);
        for r in 0..4 {
            for jc in 0..2 {
                c[row0 + r][col0 + jc] = raw[s * 8 + r * 2 + jc];
            }
        }
    }
    c
}

/// Unpack the fp32 `8×16` result of the Figure 8/9 epilogue (virtual 8×16
/// accumulator): block `s` covers rows `4*(s/4)..`, columns `4*(s%4)..`,
/// 4 f32 per block row.
pub fn unpack_c8x16_f32(raw: &[f32]) -> [[f32; 16]; 8] {
    assert_eq!(raw.len(), 128);
    let mut c = [[0f32; 16]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 4 * (s % 4);
        for r in 0..4 {
            for jc in 0..4 {
                c[row0 + r][col0 + jc] = raw[s * 16 + r * 4 + jc];
            }
        }
    }
    c
}

/// Unpack an int32 `8×16` result with the same block layout.
pub fn unpack_c8x16_i32(raw: &[i32]) -> [[i32; 16]; 8] {
    assert_eq!(raw.len(), 128);
    let mut c = [[0i32; 16]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 4 * (s % 4);
        for r in 0..4 {
            for jc in 0..4 {
                c[row0 + r][col0 + jc] = raw[s * 16 + r * 4 + jc];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_panel_transposes() {
        // a: 8 x 3, a[i][k] = 10*i + k
        let lda = 3;
        let a: Vec<f64> = (0..8 * 3).map(|x| (10 * (x / 3) + x % 3) as f64).collect();
        let p = pack_panel_f64(&a, lda, 3);
        // column k: elements 10*0+k .. 10*7+k
        for k in 0..3 {
            for i in 0..8 {
                assert_eq!(p[k * 8 + i], (10 * i + k) as f64);
            }
        }
    }

    #[test]
    fn unpack_c8x8_block_layout() {
        // raw[s*8 + r*2 + jc] encodes (row, col); fill with canonical value
        let mut raw = vec![0f64; 64];
        for s in 0..8 {
            for r in 0..4 {
                for jc in 0..2 {
                    let row = 4 * (s / 4) + r;
                    let col = 2 * (s % 4) + jc;
                    raw[s * 8 + r * 2 + jc] = (100 * row + col) as f64;
                }
            }
        }
        let c = unpack_c8x8_f64(&raw);
        for (i, row) in c.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, (100 * i + j) as f64);
            }
        }
    }

    #[test]
    fn pack_a_panel_transposes_and_pads() {
        // a: 4 x 6 row-major, a[i][k] = 10*i + k; pack rows 1..4 (3 rows,
        // mr=4 -> one zero row), columns 2..5 (kc=3)
        let a: Vec<f32> = (0..4 * 6).map(|x| (10 * (x / 6) + x % 6) as f32).collect();
        let mut out = vec![f32::NAN; 3 * 4];
        pack_a_panel_f32(&a, 6, 1, 3, 2, 3, 4, &mut out);
        for p in 0..3 {
            for i in 0..4 {
                let expect = if i < 3 { (10 * (1 + i) + 2 + p) as f32 } else { 0.0 };
                assert_eq!(out[p * 4 + i], expect, "(p={p}, i={i})");
            }
        }
    }

    #[test]
    fn pack_b_panel_copies_and_pads() {
        // b: 5 x 7 row-major, b[k][j] = 10*k + j; pack rows 1..4 (kc=3),
        // columns 4..7 (3 cols, nr=4 -> one zero column)
        let b: Vec<f32> = (0..5 * 7).map(|x| (10 * (x / 7) + x % 7) as f32).collect();
        let mut out = vec![f32::NAN; 3 * 4];
        pack_b_panel_f32(&b, 7, 1, 3, 4, 3, 4, &mut out);
        for p in 0..3 {
            for j in 0..4 {
                let expect = if j < 3 { (10 * (1 + p) + 4 + j) as f32 } else { 0.0 };
                assert_eq!(out[p * 4 + j], expect, "(p={p}, j={j})");
            }
        }
    }

    #[test]
    fn pack_b_im2col_gathers_shifted_windows() {
        // padded image: 2 channels of 4x5, img[c][y][x] = 100*c + 10*y + x;
        // output 2x3 (H=2, W=3, so N=6), taps (c, dy, dx)
        let (ih, iw) = (4usize, 5usize);
        let img: Vec<f32> = (0..2 * ih * iw)
            .map(|f| (100 * (f / (ih * iw)) + 10 * (f / iw % ih) + f % iw) as f32)
            .collect();
        let taps = [(0usize, 0usize, 0usize), (0, 1, 2), (1, 2, 1)];
        let spec = Im2colSpec {
            bases: taps.iter().map(|&(c, dy, dx)| c * ih * iw + dy * iw + dx).collect(),
            img_w: iw,
            out_w: 3,
        };
        // pack all 3 k rows, columns 2..6 (cols=4, nr=8 -> 4 zero lanes)
        let mut out = vec![f32::NAN; 3 * 8];
        pack_b_im2col_f32(&img, &spec, 0, 3, 2, 4, 8, &mut out);
        for (p, &(c, dy, dx)) in taps.iter().enumerate() {
            for j in 0..8 {
                let expect = if j < 4 {
                    let col = 2 + j;
                    (100 * c + 10 * (dy + col / 3) + dx + col % 3) as f32
                } else {
                    0.0
                };
                assert_eq!(out[p * 8 + j], expect, "(p={p}, j={j})");
            }
        }
        // a k-window (k0=1, kc=2) must address bases[1..]
        let mut out = vec![f32::NAN; 2 * 4];
        pack_b_im2col_f32(&img, &spec, 1, 2, 0, 3, 4, &mut out);
        assert_eq!(out[0], 12.0, "tap (0,1,2) at output pixel (0,0)");
        assert_eq!(out[4], 121.0, "tap (1,2,1) at output pixel (0,0)");
    }

    #[test]
    fn unpack_c8x16_block_layout() {
        let mut raw = vec![0f32; 128];
        for s in 0..8 {
            for r in 0..4 {
                for jc in 0..4 {
                    let row = 4 * (s / 4) + r;
                    let col = 4 * (s % 4) + jc;
                    raw[s * 16 + r * 4 + jc] = (100 * row + col) as f32;
                }
            }
        }
        let c = unpack_c8x16_f32(&raw);
        for (i, row) in c.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, (100 * i + j) as f32, "({i},{j})");
            }
        }
    }
}
