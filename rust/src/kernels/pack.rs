//! Panel packing and unpacking for the kernel host runners.
//!
//! The paper's kernels consume *packed* operands (as any high-performance
//! GEMM does — "that is handled in other layers of DGEMM", §V-A):
//!
//! * an `8×K` **X panel**: column `k` stored as 8 consecutive elements at
//!   `base + k*8*sizeof(T)` (what `lxvp`+`lxvp` load per iteration);
//! * an `8×K` **Y panel**: identical layout (4 `lxv` per iteration);
//! * the `8×8` **accumulator image**: eight 4×2 accumulator blocks in the
//!   Figure 4/6 order — block `s` covers rows `4*(s/4)..` and columns
//!   `2*(s%4)..`, stored row-by-row, 16 bytes per row.

/// Pack an `8×k` row-major matrix (`a[i*lda + j]`, 8 rows) into the
/// column-panel layout (column-major 8-row panel).
pub fn pack_panel_f64(a: &[f64], lda: usize, k: usize) -> Vec<f64> {
    let mut out = vec![0f64; 8 * k];
    for kk in 0..k {
        for i in 0..8 {
            out[kk * 8 + i] = a[i * lda + kk];
        }
    }
    out
}

/// Unpack the DGEMM result written by the Figure 6 epilogue into a row-major
/// `8×8` matrix.
///
/// Block `s` (`s = 0..8`) holds rows `4*(s/4) .. 4*(s/4)+4` × columns
/// `2*(s%4) .. 2*(s%4)+2`; each block row is 2 f64 (16 bytes).
pub fn unpack_c8x8_f64(raw: &[f64]) -> [[f64; 8]; 8] {
    assert_eq!(raw.len(), 64);
    let mut c = [[0f64; 8]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 2 * (s % 4);
        for r in 0..4 {
            for jc in 0..2 {
                c[row0 + r][col0 + jc] = raw[s * 8 + r * 2 + jc];
            }
        }
    }
    c
}

/// Unpack the fp32 `8×16` result of the Figure 8/9 epilogue (virtual 8×16
/// accumulator): block `s` covers rows `4*(s/4)..`, columns `4*(s%4)..`,
/// 4 f32 per block row.
pub fn unpack_c8x16_f32(raw: &[f32]) -> [[f32; 16]; 8] {
    assert_eq!(raw.len(), 128);
    let mut c = [[0f32; 16]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 4 * (s % 4);
        for r in 0..4 {
            for jc in 0..4 {
                c[row0 + r][col0 + jc] = raw[s * 16 + r * 4 + jc];
            }
        }
    }
    c
}

/// Unpack an int32 `8×16` result with the same block layout.
pub fn unpack_c8x16_i32(raw: &[i32]) -> [[i32; 16]; 8] {
    assert_eq!(raw.len(), 128);
    let mut c = [[0i32; 16]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 4 * (s % 4);
        for r in 0..4 {
            for jc in 0..4 {
                c[row0 + r][col0 + jc] = raw[s * 16 + r * 4 + jc];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_panel_transposes() {
        // a: 8 x 3, a[i][k] = 10*i + k
        let lda = 3;
        let a: Vec<f64> = (0..8 * 3).map(|x| (10 * (x / 3) + x % 3) as f64).collect();
        let p = pack_panel_f64(&a, lda, 3);
        // column k: elements 10*0+k .. 10*7+k
        for k in 0..3 {
            for i in 0..8 {
                assert_eq!(p[k * 8 + i], (10 * i + k) as f64);
            }
        }
    }

    #[test]
    fn unpack_c8x8_block_layout() {
        // raw[s*8 + r*2 + jc] encodes (row, col); fill with canonical value
        let mut raw = vec![0f64; 64];
        for s in 0..8 {
            for r in 0..4 {
                for jc in 0..2 {
                    let row = 4 * (s / 4) + r;
                    let col = 2 * (s % 4) + jc;
                    raw[s * 8 + r * 2 + jc] = (100 * row + col) as f64;
                }
            }
        }
        let c = unpack_c8x8_f64(&raw);
        for (i, row) in c.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, (100 * i + j) as f64);
            }
        }
    }

    #[test]
    fn unpack_c8x16_block_layout() {
        let mut raw = vec![0f32; 128];
        for s in 0..8 {
            for r in 0..4 {
                for jc in 0..4 {
                    let row = 4 * (s / 4) + r;
                    let col = 4 * (s % 4) + jc;
                    raw[s * 16 + r * 4 + jc] = (100 * row + col) as f32;
                }
            }
        }
        let c = unpack_c8x16_f32(&raw);
        for (i, row) in c.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, (100 * i + j) as f32, "({i},{j})");
            }
        }
    }
}
