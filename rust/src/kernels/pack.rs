//! Panel packing and unpacking for the kernel host runners.
//!
//! The paper's kernels consume *packed* operands (as any high-performance
//! GEMM does — "that is handled in other layers of DGEMM", §V-A):
//!
//! * an `8×K` **X panel**: column `k` stored as 8 consecutive elements at
//!   `base + k*8*sizeof(T)` (what `lxvp`+`lxvp` load per iteration);
//! * an `8×K` **Y panel**: identical layout (4 `lxv` per iteration);
//! * the `8×8` **accumulator image**: eight 4×2 accumulator blocks in the
//!   Figure 4/6 order — block `s` covers rows `4*(s/4)..` and columns
//!   `2*(s%4)..`, stored row-by-row, 16 bytes per row.

/// Pack an `8×k` row-major matrix (`a[i*lda + j]`, 8 rows) into the
/// column-panel layout (column-major 8-row panel).
pub fn pack_panel_f64(a: &[f64], lda: usize, k: usize) -> Vec<f64> {
    let mut out = vec![0f64; 8 * k];
    for kk in 0..k {
        for i in 0..8 {
            out[kk * 8 + i] = a[i * lda + kk];
        }
    }
    out
}

/// Pack an A micropanel for the blocked f32 GEMM (`blas::block_gemm`):
/// rows `i0 .. i0+rows` × columns `k0 .. k0+kc` of a row-major `a` with
/// row stride `lda`, transposed into the column-panel layout the paper's
/// kernels consume — column `p` stored as `mr` consecutive elements at
/// `out[p*mr ..]` (`out[p*mr + i] = a[(i0+i)*lda + k0+p]`). Rows past
/// `rows` (the m-tail of a partial panel) are zero-filled so the
/// microkernel never branches; `out` must hold `kc*mr` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_panel_f32(
    a: &[f32],
    lda: usize,
    i0: usize,
    rows: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    out: &mut [f32],
) {
    debug_assert!(rows <= mr && out.len() >= kc * mr);
    for p in 0..kc {
        let col = &mut out[p * mr..(p + 1) * mr];
        for (i, slot) in col.iter_mut().enumerate() {
            *slot = if i < rows { a[(i0 + i) * lda + k0 + p] } else { 0.0 };
        }
    }
}

/// Precompiled im2col gather: a `K×N` *virtual* B matrix over a padded
/// `[Cin, IH, IW]` image, never materialized. Row `k` of the virtual
/// matrix is one shifted image window — tap `k` of a 3×3 convolution
/// recast as a matrix multiply (the paper's Figure 9 SCONV shape):
///
/// ```text
/// B[k, col] = img[bases[k] + (col / out_w) * img_w + (col % out_w)]
/// ```
///
/// where `bases[k] = c·IH·IW + dy·IW + dx` encodes the tap's channel and
/// spatial offset, `img_w` is the padded image row stride (`IW`), and
/// `out_w` is the output width (`col` enumerates output pixels row-major
/// over `H×W`, so `N = H·W`). Built once at plan-compile time by the
/// conv rewrite pass ([`crate::runtime::plan`]); consumed per request by
/// [`pack_b_im2col_f32`], which packs the windows **directly** into the
/// [`pack_b_panel_f32`] panel layout the blocked GEMM microkernel reads —
/// the im2col matrix itself never touches memory.
#[derive(Clone, Debug)]
pub struct Im2colSpec {
    /// Per-`k` flat base offset into the image (`c·IH·IW + dy·IW + dx`).
    pub bases: Vec<usize>,
    /// Row stride of the padded image (`IW`).
    pub img_w: usize,
    /// Output width (`W`): columns per output row of the gather.
    pub out_w: usize,
}

/// Pack a B micropanel of the *virtual* im2col matrix described by
/// `spec` (see [`Im2colSpec`]): rows `k0 .. k0+kc` × columns
/// `j0 .. j0+cols`, gathered straight from the padded image into the
/// same layout as [`pack_b_panel_f32`] — row `p` stored as `nr`
/// consecutive elements at `out[p*nr ..]`, columns past `cols` (the
/// n-tail) zero-filled. `out` must hold `kc*nr` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_b_im2col_f32(
    img: &[f32],
    spec: &Im2colSpec,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [f32],
) {
    debug_assert!(cols <= nr && out.len() >= kc * nr);
    // (y, x) of the first packed column, advanced incrementally per
    // column (consecutive cols walk the output row-major) — the inner
    // loop then performs no div/mod
    let (y0, x0) = (j0 / spec.out_w, j0 % spec.out_w);
    for p in 0..kc {
        let base = spec.bases[k0 + p];
        let row = &mut out[p * nr..(p + 1) * nr];
        let (mut y, mut x) = (y0, x0);
        for slot in row[..cols].iter_mut() {
            *slot = img[base + y * spec.img_w + x];
            x += 1;
            if x == spec.out_w {
                x = 0;
                y += 1;
            }
        }
        row[cols..].fill(0.0);
    }
}

// ---------------------------------------------------------------------------
// bf16 pair-interleaved panels — the `xvbf16ger2pp` rank-2 operand layout
// (the panel shape `kernels::gemm_rp` models per step, scaled to the
// blocked GEMM's MR×NR micropanels). A *step* covers two consecutive `k`
// values; within a step, element `(lane, kl)` sits at `lane*2 + kl`, so
// one step of an A panel is `mr` adjacent (lo, hi) bf16 pairs and one
// step of a B panel is `nr` pairs — exactly what a rank-2 accumulate
// consumes per instruction. The odd-`k` tail step zero-fills its `kl=1`
// lane: a zero pair product contributes `+0.0` at the end of the chain,
// which is bitwise identical to the prefixed `pmsk` form's disabled
// product (see `blas::bf16_gemm` for the argument). Packing happens
// **straight from raw `u16` bits** (NaNs canonicalized so the raw path
// matches the widen-then-round path bit for bit) or from f32 with the
// bf16 round-to-nearest-even fused in — no widening round-trip either
// way.
// ---------------------------------------------------------------------------

use crate::isa::types::{bf16_canon_nan, f32_to_bf16_canonical};

/// Pack an A micropanel for the bf16 packed GEMM from **raw bf16 bits**:
/// rows `i0 .. i0+rows` × columns `k0 .. k0+kc` of a row-major `a` with
/// row stride `lda`, pair-interleaved — step `s` holds `k = k0+2s` and
/// `k0+2s+1`, element `(i, kl)` at `out[s*mr*2 + i*2 + kl]`. Rows past
/// `rows` (the m-tail) and the odd-`k` pad lane are zero-filled; NaN
/// bits are canonicalized ([`bf16_canon_nan`]). `out` must hold
/// `kc.div_ceil(2) * mr * 2` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_panel_bf16(
    a: &[u16],
    lda: usize,
    i0: usize,
    rows: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    out: &mut [u16],
) {
    let steps = kc.div_ceil(2);
    debug_assert!(rows <= mr && out.len() >= steps * mr * 2);
    for s in 0..steps {
        let step = &mut out[s * mr * 2..(s + 1) * mr * 2];
        for i in 0..mr {
            for kl in 0..2 {
                let kk = 2 * s + kl;
                step[i * 2 + kl] = if i < rows && kk < kc {
                    bf16_canon_nan(a[(i0 + i) * lda + k0 + kk])
                } else {
                    0
                };
            }
        }
    }
}

/// [`pack_a_panel_bf16`] with the f32→bf16 **round fused into packing**:
/// the source is row-major f32 and every packed element is rounded to
/// bf16 bits with round-to-nearest-even (canonical NaNs) on the way into
/// the panel — the compiled form of a `convert(bf16)` feeding a dot, so
/// the conversion never materializes an intermediate tensor.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_panel_f32_bf16(
    a: &[f32],
    lda: usize,
    i0: usize,
    rows: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    out: &mut [u16],
) {
    let steps = kc.div_ceil(2);
    debug_assert!(rows <= mr && out.len() >= steps * mr * 2);
    for s in 0..steps {
        let step = &mut out[s * mr * 2..(s + 1) * mr * 2];
        for i in 0..mr {
            for kl in 0..2 {
                let kk = 2 * s + kl;
                step[i * 2 + kl] = if i < rows && kk < kc {
                    f32_to_bf16_canonical(a[(i0 + i) * lda + k0 + kk])
                } else {
                    0
                };
            }
        }
    }
}

/// Pack a B micropanel for the bf16 packed GEMM from **raw bf16 bits**:
/// rows `k0 .. k0+kc` × columns `j0 .. j0+cols` of a row-major `b` with
/// row stride `ldb`, pair-interleaved — element `(j, kl)` of step `s` at
/// `out[s*nr*2 + j*2 + kl]` (`k = k0+2s+kl`). Columns past `cols` (the
/// n-tail) and the odd-`k` pad lane are zero-filled; NaN bits are
/// canonicalized. `out` must hold `kc.div_ceil(2) * nr * 2` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_b_panel_bf16(
    b: &[u16],
    ldb: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [u16],
) {
    let steps = kc.div_ceil(2);
    debug_assert!(cols <= nr && out.len() >= steps * nr * 2);
    for s in 0..steps {
        let step = &mut out[s * nr * 2..(s + 1) * nr * 2];
        for j in 0..nr {
            for kl in 0..2 {
                let kk = 2 * s + kl;
                step[j * 2 + kl] = if j < cols && kk < kc {
                    bf16_canon_nan(b[(k0 + kk) * ldb + j0 + j])
                } else {
                    0
                };
            }
        }
    }
}

/// [`pack_b_panel_bf16`] with the f32→bf16 round fused into packing
/// (see [`pack_a_panel_f32_bf16`]).
#[allow(clippy::too_many_arguments)]
pub fn pack_b_panel_f32_bf16(
    b: &[f32],
    ldb: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [u16],
) {
    let steps = kc.div_ceil(2);
    debug_assert!(cols <= nr && out.len() >= steps * nr * 2);
    for s in 0..steps {
        let step = &mut out[s * nr * 2..(s + 1) * nr * 2];
        for j in 0..nr {
            for kl in 0..2 {
                let kk = 2 * s + kl;
                step[j * 2 + kl] = if j < cols && kk < kc {
                    f32_to_bf16_canonical(b[(k0 + kk) * ldb + j0 + j])
                } else {
                    0
                };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// int8 quad-interleaved panels — the `xvi8ger4pp` rank-4 operand layout
// (§II-B.2's mixed-signedness deep-learning path: signed i8 X, unsigned
// u8 Y, i32 accumulation). A *step* covers four consecutive `k` values;
// within a step, element `(lane, kl)` sits at `lane*4 + kl`, so one step
// of an A panel is `mr` adjacent i8 quads and one step of a B panel is
// `nr` u8 quads — exactly what one rank-4 accumulate consumes per
// instruction. The `k % 4` tail step zero-fills its pad lanes: a zero
// quad product contributes `+0` to the step's exact i64 sum, identical
// to the prefixed `pmsk` form's disabled products (see `blas::i8_gemm`
// for the argument). Packing happens **straight from quantized bytes**
// or from f32 with the affine quantization (scale + zero-point,
// round-to-nearest) fused in — the quantized tensor never materializes.
// ---------------------------------------------------------------------------

/// Affine-quantize one f32 onto the signed i8 grid:
/// `clamp(round(v / scale) + zp, -128, 127)`. Rounding is
/// [`f32::round`] (half away from zero); the f32→i32 cast saturates and
/// maps NaN to 0, so every input is well-defined. This scalar IS the
/// quantization contract — the fused packers and the dequantize
/// epilogue's row/column sums must call exactly this function so both
/// sides of the correction see identical quantized values.
#[inline]
pub fn quantize_i8(v: f32, scale: f32, zp: i32) -> i8 {
    ((v / scale).round() as i32).saturating_add(zp).clamp(-128, 127) as i8
}

/// Affine-quantize one f32 onto the unsigned u8 grid:
/// `clamp(round(v / scale) + zp, 0, 255)` (see [`quantize_i8`] for the
/// rounding/NaN contract).
#[inline]
pub fn quantize_u8(v: f32, scale: f32, zp: i32) -> u8 {
    ((v / scale).round() as i32).saturating_add(zp).clamp(0, 255) as u8
}

/// Pack an A micropanel for the int8 packed GEMM from **quantized i8
/// bytes**: rows `i0 .. i0+rows` × columns `k0 .. k0+kc` of a row-major
/// `a` with row stride `lda`, quad-interleaved — step `s` holds
/// `k = k0+4s .. k0+4s+3`, element `(i, kl)` at `out[s*mr*4 + i*4 + kl]`.
/// Rows past `rows` (the m-tail) and the `k % 4` pad lanes are
/// zero-filled. `out` must hold `kc.div_ceil(4) * mr * 4` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_panel_i8(
    a: &[i8],
    lda: usize,
    i0: usize,
    rows: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    out: &mut [i8],
) {
    let steps = kc.div_ceil(4);
    debug_assert!(rows <= mr && out.len() >= steps * mr * 4);
    for s in 0..steps {
        let step = &mut out[s * mr * 4..(s + 1) * mr * 4];
        for i in 0..mr {
            for kl in 0..4 {
                let kk = 4 * s + kl;
                step[i * 4 + kl] =
                    if i < rows && kk < kc { a[(i0 + i) * lda + k0 + kk] } else { 0 };
            }
        }
    }
}

/// [`pack_a_panel_i8`] with the affine f32→i8 **quantization fused into
/// packing**: the source is row-major f32 and every packed element is
/// quantized with `scale`/`zp` ([`quantize_i8`]) on the way into the
/// panel — the compiled form of a quantize feeding a dot, so the
/// quantized tensor never materializes.
#[allow(clippy::too_many_arguments)]
pub fn pack_a_panel_f32_i8(
    a: &[f32],
    scale: f32,
    zp: i32,
    lda: usize,
    i0: usize,
    rows: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    out: &mut [i8],
) {
    let steps = kc.div_ceil(4);
    debug_assert!(rows <= mr && out.len() >= steps * mr * 4);
    for s in 0..steps {
        let step = &mut out[s * mr * 4..(s + 1) * mr * 4];
        for i in 0..mr {
            for kl in 0..4 {
                let kk = 4 * s + kl;
                step[i * 4 + kl] = if i < rows && kk < kc {
                    quantize_i8(a[(i0 + i) * lda + k0 + kk], scale, zp)
                } else {
                    0
                };
            }
        }
    }
}

/// Pack a B micropanel for the int8 packed GEMM from **quantized u8
/// bytes**: rows `k0 .. k0+kc` × columns `j0 .. j0+cols` of a row-major
/// `b` with row stride `ldb`, quad-interleaved — element `(j, kl)` of
/// step `s` at `out[s*nr*4 + j*4 + kl]` (`k = k0+4s+kl`). Columns past
/// `cols` (the n-tail) and the `k % 4` pad lanes are zero-filled. `out`
/// must hold `kc.div_ceil(4) * nr * 4` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_b_panel_u8(
    b: &[u8],
    ldb: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [u8],
) {
    let steps = kc.div_ceil(4);
    debug_assert!(cols <= nr && out.len() >= steps * nr * 4);
    for s in 0..steps {
        let step = &mut out[s * nr * 4..(s + 1) * nr * 4];
        for j in 0..nr {
            for kl in 0..4 {
                let kk = 4 * s + kl;
                step[j * 4 + kl] =
                    if j < cols && kk < kc { b[(k0 + kk) * ldb + j0 + j] } else { 0 };
            }
        }
    }
}

/// [`pack_b_panel_u8`] with the affine f32→u8 quantization fused into
/// packing (see [`pack_a_panel_f32_i8`]).
#[allow(clippy::too_many_arguments)]
pub fn pack_b_panel_f32_u8(
    b: &[f32],
    scale: f32,
    zp: i32,
    ldb: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [u8],
) {
    let steps = kc.div_ceil(4);
    debug_assert!(cols <= nr && out.len() >= steps * nr * 4);
    for s in 0..steps {
        let step = &mut out[s * nr * 4..(s + 1) * nr * 4];
        for j in 0..nr {
            for kl in 0..4 {
                let kk = 4 * s + kl;
                step[j * 4 + kl] = if j < cols && kk < kc {
                    quantize_u8(b[(k0 + kk) * ldb + j0 + j], scale, zp)
                } else {
                    0
                };
            }
        }
    }
}

/// Pack a B micropanel for the blocked f32 GEMM: rows `k0 .. k0+kc` ×
/// columns `j0 .. j0+cols` of a row-major `b` with row stride `ldb`, kept
/// row-major per step — row `p` stored as `nr` consecutive elements at
/// `out[p*nr ..]` (`out[p*nr + j] = b[(k0+p)*ldb + j0+j]`). Columns past
/// `cols` (the n-tail) are zero-filled; `out` must hold `kc*nr` elements.
#[allow(clippy::too_many_arguments)]
pub fn pack_b_panel_f32(
    b: &[f32],
    ldb: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    cols: usize,
    nr: usize,
    out: &mut [f32],
) {
    debug_assert!(cols <= nr && out.len() >= kc * nr);
    for p in 0..kc {
        let row = &mut out[p * nr..(p + 1) * nr];
        let src = &b[(k0 + p) * ldb + j0..];
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = if j < cols { src[j] } else { 0.0 };
        }
    }
}

/// A whole `k×n` B matrix pre-packed into the blocked GEMM's panel grid
/// — every `(kc-block, nr-panel)` micropanel [`pack_b_panel_f32`] would
/// produce at request time, materialized **once** and replayed as a
/// straight copy by [`PanelB::Packed`](crate::blas::block_gemm::PanelB).
/// Built for one specific variant geometry (`nr`, `kc`): the panel
/// queries of a GEMM running under that geometry are exactly the grid
/// cells (the column chunking guarantees `j0 % nr == 0` and `k0` a
/// multiple of `kc` — see
/// [`chunk_plan_nr`](crate::blas::block_gemm::chunk_plan_nr)).
///
/// Layout: depth blocks outermost (block `bk` covers rows `bk·kc ..`,
/// only the last may be short), `n.div_ceil(nr)` panels inside, each
/// panel `kcl·nr` elements in [`pack_b_panel_f32`]'s row-per-step order
/// with the n-tail zero-filled.
#[derive(Clone, Debug)]
pub struct PackedB {
    data: Vec<f32>,
    k: usize,
    n: usize,
    nr: usize,
    kc: usize,
}

impl PackedB {
    /// Pack the full row-major `k×n` matrix `b` for a GEMM running with
    /// microkernel width `nr` and depth blocking `kc`.
    pub fn pack(b: &[f32], k: usize, n: usize, nr: usize, kc: usize) -> PackedB {
        assert_eq!(b.len(), k * n, "B must be k*n");
        assert!(nr > 0 && kc > 0);
        let np = n.div_ceil(nr).max(1);
        let mut data = vec![0f32; k * np * nr];
        for bk in 0..k.div_ceil(kc) {
            let k0 = bk * kc;
            let kcl = kc.min(k - k0);
            for jp in 0..np {
                let j0 = jp * nr;
                let cols = nr.min(n - j0);
                let off = k0 * np * nr + jp * kcl * nr;
                pack_b_panel_f32(b, n, k0, kcl, j0, cols, nr, &mut data[off..off + kcl * nr]);
            }
        }
        PackedB { data, k, n, nr, kc }
    }

    /// The packed micropanel covering rows `k0 .. k0+kcl` × the `nr`
    /// columns starting at `j0` — the slice a GEMM panel query copies.
    /// `k0` must be a grid depth block start and `j0` panel-aligned.
    pub fn panel(&self, k0: usize, kcl: usize, j0: usize) -> &[f32] {
        assert!(
            k0 % self.kc == 0 && kcl == self.kc.min(self.k - k0),
            "depth query ({k0}, {kcl}) off the packed kc={} grid of k={}",
            self.kc,
            self.k
        );
        assert!(
            j0 % self.nr == 0 && j0 < self.n.max(1),
            "column query {j0} off the packed nr={} grid of n={}",
            self.nr,
            self.n
        );
        let np = self.n.div_ceil(self.nr).max(1);
        let off = k0 * np * self.nr + (j0 / self.nr) * kcl * self.nr;
        &self.data[off..off + kcl * self.nr]
    }

    /// The geometry this matrix was packed for: `(k, n, nr, kc)`.
    pub fn geometry(&self) -> (usize, usize, usize, usize) {
        (self.k, self.n, self.nr, self.kc)
    }
}

/// The split re/im packed operand of a DFT-as-complex-matmul step: the
/// real and imaginary Fourier matrices (`F = Fr + i·Fi`, each `n×n`) as
/// two [`PackedB`] panel grids sharing one variant geometry. Packed once
/// at plan-compile time from the lowered graph's constant literals and
/// pinned alongside the plan — the four real GEMMs of
/// `(xr + i·xi)·(Fr + i·Fi)` replay the panels with zero per-request
/// packing work on the B side.
#[derive(Clone, Debug)]
pub struct DftPanels {
    /// Packed `Fr` (the cosine matrix).
    pub re: PackedB,
    /// Packed `Fi` (the negated-sine matrix).
    pub im: PackedB,
}

impl DftPanels {
    /// Pack both `k×n` Fourier matrices for the step's variant geometry.
    pub fn pack(fr: &[f32], fi: &[f32], k: usize, n: usize, nr: usize, kc: usize) -> DftPanels {
        DftPanels { re: PackedB::pack(fr, k, n, nr, kc), im: PackedB::pack(fi, k, n, nr, kc) }
    }
}

/// Unpack the DGEMM result written by the Figure 6 epilogue into a row-major
/// `8×8` matrix.
///
/// Block `s` (`s = 0..8`) holds rows `4*(s/4) .. 4*(s/4)+4` × columns
/// `2*(s%4) .. 2*(s%4)+2`; each block row is 2 f64 (16 bytes).
pub fn unpack_c8x8_f64(raw: &[f64]) -> [[f64; 8]; 8] {
    assert_eq!(raw.len(), 64);
    let mut c = [[0f64; 8]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 2 * (s % 4);
        for r in 0..4 {
            for jc in 0..2 {
                c[row0 + r][col0 + jc] = raw[s * 8 + r * 2 + jc];
            }
        }
    }
    c
}

/// Unpack the fp32 `8×16` result of the Figure 8/9 epilogue (virtual 8×16
/// accumulator): block `s` covers rows `4*(s/4)..`, columns `4*(s%4)..`,
/// 4 f32 per block row.
pub fn unpack_c8x16_f32(raw: &[f32]) -> [[f32; 16]; 8] {
    assert_eq!(raw.len(), 128);
    let mut c = [[0f32; 16]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 4 * (s % 4);
        for r in 0..4 {
            for jc in 0..4 {
                c[row0 + r][col0 + jc] = raw[s * 16 + r * 4 + jc];
            }
        }
    }
    c
}

/// Unpack an int32 `8×16` result with the same block layout.
pub fn unpack_c8x16_i32(raw: &[i32]) -> [[i32; 16]; 8] {
    assert_eq!(raw.len(), 128);
    let mut c = [[0i32; 16]; 8];
    for s in 0..8 {
        let row0 = 4 * (s / 4);
        let col0 = 4 * (s % 4);
        for r in 0..4 {
            for jc in 0..4 {
                c[row0 + r][col0 + jc] = raw[s * 16 + r * 4 + jc];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_panel_transposes() {
        // a: 8 x 3, a[i][k] = 10*i + k
        let lda = 3;
        let a: Vec<f64> = (0..8 * 3).map(|x| (10 * (x / 3) + x % 3) as f64).collect();
        let p = pack_panel_f64(&a, lda, 3);
        // column k: elements 10*0+k .. 10*7+k
        for k in 0..3 {
            for i in 0..8 {
                assert_eq!(p[k * 8 + i], (10 * i + k) as f64);
            }
        }
    }

    #[test]
    fn unpack_c8x8_block_layout() {
        // raw[s*8 + r*2 + jc] encodes (row, col); fill with canonical value
        let mut raw = vec![0f64; 64];
        for s in 0..8 {
            for r in 0..4 {
                for jc in 0..2 {
                    let row = 4 * (s / 4) + r;
                    let col = 2 * (s % 4) + jc;
                    raw[s * 8 + r * 2 + jc] = (100 * row + col) as f64;
                }
            }
        }
        let c = unpack_c8x8_f64(&raw);
        for (i, row) in c.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, (100 * i + j) as f64);
            }
        }
    }

    #[test]
    fn pack_a_panel_transposes_and_pads() {
        // a: 4 x 6 row-major, a[i][k] = 10*i + k; pack rows 1..4 (3 rows,
        // mr=4 -> one zero row), columns 2..5 (kc=3)
        let a: Vec<f32> = (0..4 * 6).map(|x| (10 * (x / 6) + x % 6) as f32).collect();
        let mut out = vec![f32::NAN; 3 * 4];
        pack_a_panel_f32(&a, 6, 1, 3, 2, 3, 4, &mut out);
        for p in 0..3 {
            for i in 0..4 {
                let expect = if i < 3 { (10 * (1 + i) + 2 + p) as f32 } else { 0.0 };
                assert_eq!(out[p * 4 + i], expect, "(p={p}, i={i})");
            }
        }
    }

    #[test]
    fn pack_b_panel_copies_and_pads() {
        // b: 5 x 7 row-major, b[k][j] = 10*k + j; pack rows 1..4 (kc=3),
        // columns 4..7 (3 cols, nr=4 -> one zero column)
        let b: Vec<f32> = (0..5 * 7).map(|x| (10 * (x / 7) + x % 7) as f32).collect();
        let mut out = vec![f32::NAN; 3 * 4];
        pack_b_panel_f32(&b, 7, 1, 3, 4, 3, 4, &mut out);
        for p in 0..3 {
            for j in 0..4 {
                let expect = if j < 3 { (10 * (1 + p) + 4 + j) as f32 } else { 0.0 };
                assert_eq!(out[p * 4 + j], expect, "(p={p}, j={j})");
            }
        }
    }

    #[test]
    fn pack_b_im2col_gathers_shifted_windows() {
        // padded image: 2 channels of 4x5, img[c][y][x] = 100*c + 10*y + x;
        // output 2x3 (H=2, W=3, so N=6), taps (c, dy, dx)
        let (ih, iw) = (4usize, 5usize);
        let img: Vec<f32> = (0..2 * ih * iw)
            .map(|f| (100 * (f / (ih * iw)) + 10 * (f / iw % ih) + f % iw) as f32)
            .collect();
        let taps = [(0usize, 0usize, 0usize), (0, 1, 2), (1, 2, 1)];
        let spec = Im2colSpec {
            bases: taps.iter().map(|&(c, dy, dx)| c * ih * iw + dy * iw + dx).collect(),
            img_w: iw,
            out_w: 3,
        };
        // pack all 3 k rows, columns 2..6 (cols=4, nr=8 -> 4 zero lanes)
        let mut out = vec![f32::NAN; 3 * 8];
        pack_b_im2col_f32(&img, &spec, 0, 3, 2, 4, 8, &mut out);
        for (p, &(c, dy, dx)) in taps.iter().enumerate() {
            for j in 0..8 {
                let expect = if j < 4 {
                    let col = 2 + j;
                    (100 * c + 10 * (dy + col / 3) + dx + col % 3) as f32
                } else {
                    0.0
                };
                assert_eq!(out[p * 8 + j], expect, "(p={p}, j={j})");
            }
        }
        // a k-window (k0=1, kc=2) must address bases[1..]
        let mut out = vec![f32::NAN; 2 * 4];
        pack_b_im2col_f32(&img, &spec, 1, 2, 0, 3, 4, &mut out);
        assert_eq!(out[0], 12.0, "tap (0,1,2) at output pixel (0,0)");
        assert_eq!(out[4], 121.0, "tap (1,2,1) at output pixel (0,0)");
    }

    #[test]
    fn bf16_panels_pair_interleave_and_pad() {
        use crate::isa::types::f32_to_bf16;
        // a: 4 x 5 row-major of exactly-representable values; pack rows
        // 1..4 (3 rows, mr=4 -> one zero row), columns 1..4 (kc=3, odd ->
        // step 1 pads its kl=1 lane)
        let a: Vec<u16> =
            (0..4 * 5).map(|x| f32_to_bf16((10 * (x / 5) + x % 5) as f32)).collect();
        let mut out = vec![0xdeadu16; 2 * 4 * 2];
        pack_a_panel_bf16(&a, 5, 1, 3, 1, 3, 4, &mut out);
        for s in 0..2 {
            for i in 0..4 {
                for kl in 0..2 {
                    let kk = 2 * s + kl;
                    let expect = if i < 3 && kk < 3 {
                        f32_to_bf16((10 * (1 + i) + 1 + kk) as f32)
                    } else {
                        0
                    };
                    assert_eq!(out[s * 8 + i * 2 + kl], expect, "(s={s}, i={i}, kl={kl})");
                }
            }
        }
        // B: 5 x 6 row-major; rows 2..5 (kc=3), columns 1..5 (cols=4,
        // nr=6 -> two zero columns)
        let b: Vec<u16> =
            (0..5 * 6).map(|x| f32_to_bf16((10 * (x / 6) + x % 6) as f32)).collect();
        let mut out = vec![0xdeadu16; 2 * 6 * 2];
        pack_b_panel_bf16(&b, 6, 2, 3, 1, 4, 6, &mut out);
        for s in 0..2 {
            for j in 0..6 {
                for kl in 0..2 {
                    let kk = 2 * s + kl;
                    let expect = if j < 4 && kk < 3 {
                        f32_to_bf16((10 * (2 + kk) + 1 + j) as f32)
                    } else {
                        0
                    };
                    assert_eq!(out[s * 12 + j * 2 + kl], expect, "(s={s}, j={j}, kl={kl})");
                }
            }
        }
    }

    #[test]
    fn bf16_f32_packers_round_like_the_raw_path() {
        use crate::isa::types::bf16_to_f32;
        // packing f32 sources must equal rounding first and packing the
        // raw bits — including a NaN payload, which both paths collapse
        // to the canonical quiet NaN
        let vals = [1.0f32, -2.5, 1.0 + 2.0f32.powi(-9), f32::INFINITY, -0.0,
            f32::from_bits(0x7f81_2345), 3.1e-41];
        let bits: Vec<u16> = vals.iter().map(|&v| f32_to_bf16_canonical(v)).collect();
        let widened: Vec<f32> = bits.iter().map(|&b| bf16_to_f32(b)).collect();
        let (mut from_f32, mut from_bits) = (vec![0u16; 4 * 7 * 2], vec![0u16; 4 * 7 * 2]);
        // treat vals as a 1 x 7 A row (mr=1) and as a 7 x 1 B column
        pack_a_panel_f32_bf16(&vals, 7, 0, 1, 0, 7, 1, &mut from_f32[..4 * 2]);
        pack_a_panel_bf16(&bits, 7, 0, 1, 0, 7, 1, &mut from_bits[..4 * 2]);
        assert_eq!(from_f32[..4 * 2], from_bits[..4 * 2]);
        pack_b_panel_f32_bf16(&widened, 1, 0, 7, 0, 1, 1, &mut from_f32[..4 * 2]);
        pack_b_panel_bf16(&bits, 1, 0, 7, 0, 1, 1, &mut from_bits[..4 * 2]);
        assert_eq!(from_f32[..4 * 2], from_bits[..4 * 2]);
        // the NaN payload really was canonicalized
        assert!(from_bits.iter().all(|&b| b != 0x7f81 | 0x0040));
    }

    #[test]
    fn i8_panels_quad_interleave_and_pad() {
        // a: 4 x 6 row-major i8, a[i][k] = 10*i + k - 3; pack rows 1..4
        // (3 rows, mr=4 -> one zero row), columns 1..6 (kc=5 -> step 1
        // pads its kl=1..3 lanes)
        let a: Vec<i8> = (0..4 * 6).map(|x| (10 * (x / 6) + x % 6) as i8 - 3).collect();
        let mut out = vec![0x55i8; 2 * 4 * 4];
        pack_a_panel_i8(&a, 6, 1, 3, 1, 5, 4, &mut out);
        for s in 0..2 {
            for i in 0..4 {
                for kl in 0..4 {
                    let kk = 4 * s + kl;
                    let expect = if i < 3 && kk < 5 {
                        (10 * (1 + i) + 1 + kk) as i8 - 3
                    } else {
                        0
                    };
                    assert_eq!(out[s * 16 + i * 4 + kl], expect, "(s={s}, i={i}, kl={kl})");
                }
            }
        }
        // B: 6 x 7 row-major u8; rows 1..6 (kc=5), columns 2..6 (cols=4,
        // nr=6 -> two zero columns)
        let b: Vec<u8> = (0..6 * 7).map(|x| (10 * (x / 7) + x % 7) as u8).collect();
        let mut out = vec![0xaau8; 2 * 6 * 4];
        pack_b_panel_u8(&b, 7, 1, 5, 2, 4, 6, &mut out);
        for s in 0..2 {
            for j in 0..6 {
                for kl in 0..4 {
                    let kk = 4 * s + kl;
                    let expect = if j < 4 && kk < 5 {
                        (10 * (1 + kk) + 2 + j) as u8
                    } else {
                        0
                    };
                    assert_eq!(out[s * 24 + j * 4 + kl], expect, "(s={s}, j={j}, kl={kl})");
                }
            }
        }
    }

    #[test]
    fn i8_f32_packers_quantize_like_the_scalar_path() {
        // fused quantization must equal quantizing first and packing the
        // raw bytes — including saturating inputs, NaN, and infinities
        let vals = [0.0f32, 1.26, -1.24, 500.0, -500.0, f32::NAN, f32::INFINITY,
            f32::NEG_INFINITY, 0.049, -0.051, 63.76];
        let (scale, zp) = (0.1f32, 3i32);
        let qa: Vec<i8> = vals.iter().map(|&v| quantize_i8(v, scale, zp)).collect();
        let qb: Vec<u8> = vals.iter().map(|&v| quantize_u8(v, scale, zp)).collect();
        // saturation boundaries really engage
        assert_eq!(quantize_i8(500.0, scale, zp), 127);
        assert_eq!(quantize_i8(-500.0, scale, zp), -128);
        assert_eq!(quantize_u8(-500.0, scale, zp), 0);
        assert_eq!(quantize_u8(500.0, scale, zp), 255);
        assert_eq!(quantize_i8(f32::NAN, scale, zp), 3, "NaN quantizes to zp");
        // treat vals as a 1 x 11 A row (mr=1) and an 11 x 1 B column
        let steps = 11usize.div_ceil(4);
        let (mut fa, mut ra) = (vec![0i8; steps * 4], vec![0i8; steps * 4]);
        pack_a_panel_f32_i8(&vals, scale, zp, 11, 0, 1, 0, 11, 1, &mut fa);
        pack_a_panel_i8(&qa, 11, 0, 1, 0, 11, 1, &mut ra);
        assert_eq!(fa, ra);
        let (mut fb, mut rb) = (vec![0u8; steps * 4], vec![0u8; steps * 4]);
        pack_b_panel_f32_u8(&vals, scale, zp, 1, 0, 11, 0, 1, 1, &mut fb);
        pack_b_panel_u8(&qb, 1, 0, 11, 0, 1, 1, &mut rb);
        assert_eq!(fb, rb);
    }

    #[test]
    fn f32_panels_zero_fill_every_seam_shape() {
        // the tuner's register-tile family (mr in {4,8}, nr in {8,16})
        // at every tile seam (rows/cols = tile−1, tile) and every KC
        // tail (kc = 1, KC−1, KC, KC+1), from offset windows: every
        // in-range element lands per the layout formula and every pad
        // slot is exactly +0.0 — sentinel-filled outputs prove full
        // overwrite
        const KC: usize = crate::blas::block_gemm::KC;
        let (i0, k0, j0) = (2usize, 3usize, 1usize);
        let src = |r: usize, c: usize| (r * 997 + c) as f32;
        for mr in [4usize, 8] {
            for rows in [1usize, mr - 1, mr] {
                for kc in [1usize, KC - 1, KC, KC + 1] {
                    let lda = k0 + kc + 2;
                    let a: Vec<f32> =
                        (0..(i0 + mr) * lda).map(|x| src(x / lda, x % lda)).collect();
                    let mut out = vec![f32::NAN; kc * mr];
                    pack_a_panel_f32(&a, lda, i0, rows, k0, kc, mr, &mut out);
                    for p in 0..kc {
                        for i in 0..mr {
                            let got = out[p * mr + i];
                            if i < rows {
                                assert_eq!(got, src(i0 + i, k0 + p), "mr={mr} p={p} i={i}");
                            } else {
                                assert_eq!(got.to_bits(), 0, "m-tail pad mr={mr} p={p} i={i}");
                            }
                        }
                    }
                }
            }
        }
        for nr in [8usize, 16] {
            for cols in [1usize, nr - 1, nr] {
                for kc in [1usize, KC - 1, KC, KC + 1] {
                    let ldb = j0 + nr + 2;
                    let b: Vec<f32> =
                        (0..(k0 + kc) * ldb).map(|x| src(x / ldb, x % ldb)).collect();
                    let mut out = vec![f32::NAN; kc * nr];
                    pack_b_panel_f32(&b, ldb, k0, kc, j0, cols, nr, &mut out);
                    for p in 0..kc {
                        for j in 0..nr {
                            let got = out[p * nr + j];
                            if j < cols {
                                assert_eq!(got, src(k0 + p, j0 + j), "nr={nr} p={p} j={j}");
                            } else {
                                assert_eq!(got.to_bits(), 0, "n-tail pad nr={nr} p={p} j={j}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn bf16_panels_zero_fill_every_seam_shape() {
        // the pair-interleaved layout at the same seam sweep: the odd-k
        // pad lane, the m/n tails, and the KC±1 windows must all land
        // zero bits (never a stale sentinel), in-range elements per the
        // (s, lane, kl) formula
        const KC: usize = crate::blas::block_gemm::KC;
        let (i0, k0, j0) = (1usize, 2usize, 3usize);
        let src = |r: usize, c: usize| ((r * 131 + c * 7) % 0x7f00) as u16;
        for (mr_nr, a_side) in [(8usize, true), (8, false), (16, false)] {
            for edge in [1usize, mr_nr - 1, mr_nr] {
                for kc in [1usize, KC - 1, KC, KC + 1] {
                    let steps = kc.div_ceil(2);
                    let mut out = vec![0xdeadu16; steps * mr_nr * 2];
                    if a_side {
                        let lda = k0 + kc + 1;
                        let a: Vec<u16> =
                            (0..(i0 + mr_nr) * lda).map(|x| src(x / lda, x % lda)).collect();
                        pack_a_panel_bf16(&a, lda, i0, edge, k0, kc, mr_nr, &mut out);
                        for s in 0..steps {
                            for i in 0..mr_nr {
                                for kl in 0..2 {
                                    let kk = 2 * s + kl;
                                    let want = if i < edge && kk < kc {
                                        src(i0 + i, k0 + kk)
                                    } else {
                                        0
                                    };
                                    let got = out[s * mr_nr * 2 + i * 2 + kl];
                                    assert_eq!(got, want, "A s={s} i={i} kl={kl} kc={kc}");
                                }
                            }
                        }
                    } else {
                        let ldb = j0 + mr_nr + 1;
                        let b: Vec<u16> =
                            (0..(k0 + kc) * ldb).map(|x| src(x / ldb, x % ldb)).collect();
                        pack_b_panel_bf16(&b, ldb, k0, kc, j0, edge, mr_nr, &mut out);
                        for s in 0..steps {
                            for j in 0..mr_nr {
                                for kl in 0..2 {
                                    let kk = 2 * s + kl;
                                    let want = if j < edge && kk < kc {
                                        src(k0 + kk, j0 + j)
                                    } else {
                                        0
                                    };
                                    let got = out[s * mr_nr * 2 + j * 2 + kl];
                                    assert_eq!(got, want, "B s={s} j={j} kl={kl} kc={kc}");
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn i8_panels_zero_fill_every_seam_shape() {
        // the quad-interleaved layout at the seam sweep: k%4 pad lanes,
        // m/n tails, KC±1 windows — pad bytes are literal zero (the
        // rank-4 step's disabled-product image; the dequantize
        // zero-point correction happens in the engine, never in the
        // panel), in-range bytes per the (s, lane, kl) formula
        const KC: usize = crate::blas::block_gemm::KC;
        let (i0, k0, j0) = (2usize, 1usize, 2usize);
        for mr_nr in [8usize, 16] {
            for edge in [1usize, mr_nr - 1, mr_nr] {
                for kc in [1usize, KC - 1, KC, KC + 1] {
                    let steps = kc.div_ceil(4);
                    let lda = k0 + kc + 3;
                    let a: Vec<i8> =
                        (0..(i0 + mr_nr) * lda).map(|x| (x % 256) as u8 as i8).collect();
                    let mut out = vec![0x55i8; steps * mr_nr * 4];
                    pack_a_panel_i8(&a, lda, i0, edge, k0, kc, mr_nr, &mut out);
                    for s in 0..steps {
                        for i in 0..mr_nr {
                            for kl in 0..4 {
                                let kk = 4 * s + kl;
                                let want = if i < edge && kk < kc {
                                    a[(i0 + i) * lda + k0 + kk]
                                } else {
                                    0
                                };
                                let got = out[s * mr_nr * 4 + i * 4 + kl];
                                assert_eq!(got, want, "A s={s} i={i} kl={kl} kc={kc}");
                            }
                        }
                    }
                    let ldb = j0 + mr_nr + 2;
                    let b: Vec<u8> = (0..(k0 + kc) * ldb).map(|x| (x % 256) as u8).collect();
                    let mut out = vec![0xaau8; steps * mr_nr * 4];
                    pack_b_panel_u8(&b, ldb, k0, kc, j0, edge, mr_nr, &mut out);
                    for s in 0..steps {
                        for j in 0..mr_nr {
                            for kl in 0..4 {
                                let kk = 4 * s + kl;
                                let want = if j < edge && kk < kc {
                                    b[(k0 + kk) * ldb + j0 + j]
                                } else {
                                    0
                                };
                                let got = out[s * mr_nr * 4 + j * 4 + kl];
                                assert_eq!(got, want, "B s={s} j={j} kl={kl} kc={kc}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn unpack_c8x16_block_layout() {
        let mut raw = vec![0f32; 128];
        for s in 0..8 {
            for r in 0..4 {
                for jc in 0..4 {
                    let row = 4 * (s / 4) + r;
                    let col = 4 * (s % 4) + jc;
                    raw[s * 16 + r * 4 + jc] = (100 * row + col) as f32;
                }
            }
        }
        let c = unpack_c8x16_f32(&raw);
        for (i, row) in c.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(v, (100 * i + j) as f32, "({i},{j})");
            }
        }
    }
}
