//! Stencil computation on the MMA facility — the second "future work"
//! direction the paper's conclusion names.
//!
//! A 1-D k-point stencil over a batch of rows is the same shape as SCONV's
//! inner step (§V-B): the coefficient vector plays the H̄ role and the
//! shifted input rows are the right operand. We build an `8×taps×16`
//! stencil kernel directly from the Figure 8/9 machinery: 8 independent
//! stencil operators (e.g. different smoothing radii) applied to the same
//! row in one pass — the multi-kernel trick of §V-B.

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};
use crate::isa::{ExecError, Machine};
use crate::kernels::pack::unpack_c8x16_f32;

/// Generate the `8-operator × taps × 16-point` stencil kernel.
///
/// `r3` = coefficient matrix C (8×taps, column-major, 32 B per column —
/// one fp32x8 column per tap), `r6` = input row base, `r10` = output.
/// Like SCONV, byte shifts that break `lxv`'s 16-byte alignment use shift
/// base registers prepared with `addi` (r11..).
pub fn stencil_8xtapsx16_program(taps: usize) -> Vec<Inst> {
    assert!(taps >= 1 && taps <= 16);
    let mut p = Vec::new();
    // prepare shift registers r11..: base + 4*shift for each misaligned tap
    for t in 0..taps {
        let shift_bytes = (4 * t % 16) as i32;
        if shift_bytes != 0 {
            // r11 + (t % 4 - 1): reuse 3 registers cyclically (shifts 4, 8, 12)
            let reg = 11 + ((shift_bytes / 4 - 1) as u8 % 3);
            p.push(Inst::Addi { rt: reg, ra: 6, si: shift_bytes });
        }
    }
    for t in 0..taps {
        // coefficient column t -> vs32/vs33
        p.push(Inst::Lxv { xt: 32, ra: 3, dq: 32 * t as i32 });
        p.push(Inst::Lxv { xt: 33, ra: 3, dq: 32 * t as i32 + 16 });
        // input window starting at element t: 16 fp32 from the shifted base
        let shift_bytes = (4 * t % 16) as i32;
        let (reg, disp) = if shift_bytes == 0 {
            (6u8, 4 * t as i32)
        } else {
            (11 + ((shift_bytes / 4 - 1) as u8 % 3), 4 * t as i32 - shift_bytes)
        };
        for j in 0..4u8 {
            p.push(Inst::Lxv { xt: 36 + j, ra: reg, dq: disp + 16 * i32::from(j) });
        }
        let op = if t == 0 { AccOp::New } else { AccOp::PP };
        for s in [0u8, 1, 4, 5, 2, 3, 6, 7] {
            let x = if s < 4 { 32 } else { 33 };
            p.push(Inst::Ger(Ger::new(GerKind::F32Ger, op, s, x, 36 + (s % 4))));
        }
    }
    for s in 0..8u8 {
        p.push(Inst::XxMfAcc { acc: s });
        for r in 0..4u8 {
            p.push(Inst::Stxv { xs: s * 4 + r, ra: 10, dq: 64 * i32::from(s) + 16 * i32::from(r) });
        }
    }
    p.push(Inst::Blr);
    p
}

/// Apply 8 stencil operators (`coeffs` is `8×taps`, row-major) to `row`
/// (length ≥ 16 + taps − 1), producing 16 outputs per operator:
/// `out[f][x] = Σ_t coeffs[f][t] · row[x + t]`.
pub fn run_stencil_8x16(
    coeffs: &[f32],
    taps: usize,
    row: &[f32],
) -> Result<[[f32; 16]; 8], ExecError> {
    assert_eq!(coeffs.len(), 8 * taps);
    assert!(row.len() >= 16 + taps - 1);
    // pack coefficients column-major (column t = 8 operator weights)
    let mut cm = vec![0f32; 8 * taps];
    for f in 0..8 {
        for t in 0..taps {
            cm[t * 8 + f] = coeffs[f * taps + t];
        }
    }
    let cb = 0u64;
    let rb = (8 * taps * 4).next_multiple_of(16) as u64;
    let ob = rb + (row.len() * 4).next_multiple_of(16) as u64;
    let mut m = Machine::new((ob + 512) as usize);
    m.write_f32s(cb, &cm);
    m.write_f32s(rb, row);
    m.gpr[3] = cb;
    m.gpr[6] = rb;
    m.gpr[10] = ob;
    let prog = stencil_8xtapsx16_program(taps);
    m.run(&prog, 8192)?;
    let raw = m.read_f32s(ob, 128);
    Ok(unpack_c8x16_f32(&raw))
}

/// Scalar oracle.
pub fn stencil_reference(coeffs: &[f32], taps: usize, row: &[f32], outs: usize) -> Vec<Vec<f32>> {
    (0..8)
        .map(|f| {
            (0..outs)
                .map(|x| (0..taps).map(|t| coeffs[f * taps + t] * row[x + t]).sum())
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    #[test]
    fn three_point_laplacian() {
        // classic [1, -2, 1] second-difference stencil in operator 0
        let taps = 3;
        let mut coeffs = vec![0f32; 8 * taps];
        coeffs[0] = 1.0;
        coeffs[1] = -2.0;
        coeffs[2] = 1.0;
        // quadratic input -> constant second difference
        let row: Vec<f32> = (0..24).map(|i| (i * i) as f32).collect();
        let out = run_stencil_8x16(&coeffs, taps, &row).unwrap();
        for x in 0..16 {
            assert_eq!(out[0][x], 2.0, "second difference of x^2 is 2");
        }
        for f in 1..8 {
            assert!(out[f].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn stencil_matches_reference_property() {
        check("stencil 8x16 == scalar", 15, |rng: &mut Rng| {
            let taps = rng.range(1, 10);
            let coeffs = rng.f32_vec(8 * taps);
            let row = rng.f32_vec(16 + taps + 8);
            let got = run_stencil_8x16(&coeffs, taps, &row).unwrap();
            let expect = stencil_reference(&coeffs, taps, &row, 16);
            for f in 0..8 {
                for x in 0..16 {
                    assert!(
                        (got[f][x] - expect[f][x]).abs() <= 1e-4 * expect[f][x].abs().max(1.0),
                        "op {f} x {x}: {} vs {}",
                        got[f][x],
                        expect[f][x]
                    );
                }
            }
        });
    }

    #[test]
    fn eight_operators_in_one_pass() {
        // 8 different box filters applied simultaneously (the multi-kernel
        // trick of §V-B applied to stencils)
        let taps = 5;
        let mut coeffs = vec![0f32; 8 * taps];
        for f in 0..8 {
            for t in 0..=f.min(taps - 1) {
                coeffs[f * taps + t] = 1.0 / (f.min(taps - 1) + 1) as f32;
            }
        }
        let row: Vec<f32> = (0..30).map(|i| i as f32).collect();
        let got = run_stencil_8x16(&coeffs, taps, &row).unwrap();
        let expect = stencil_reference(&coeffs, taps, &row, 16);
        for f in 0..8 {
            for x in 0..16 {
                assert!((got[f][x] - expect[f][x]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn instruction_mix() {
        let prog = stencil_8xtapsx16_program(7);
        let gers = prog.iter().filter(|i| matches!(i, Inst::Ger(_))).count();
        assert_eq!(gers, 7 * 8, "8 rank-1 updates per tap");
    }
}
