//! The paper's §V-B SCONV kernel: a 3-channel 3×3 single-precision 2-D
//! convolution computed **directly on the input image** with MMA outer
//! products — no im2col materialization (the point of §V-B: "convolution
//! can be done directly on the input matrix A").
//!
//! The structure follows Figure 9 exactly: the 8 accumulators form a
//! virtual `8×16` fp32 accumulator (8 filters × 16 output pixels); there
//! are 27 rank-1 `8×16` outer-product steps — filter matrix column
//! `H[:,c]` (8 fp32, 2 VSRs) times a 16-pixel window of an image row
//! (4 VSRs), where each channel row is used three times at byte shifts
//! 0, +4, +8 (equation 8's three shifted copies).

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};
use crate::isa::{ExecError, Machine};
use crate::kernels::pack::unpack_c8x16_f32;

/// One `mma_xvf32_8x16` macro expansion (Figure 8): load the H column
/// (2 `lxv` at `h_off` bytes from r3) and the 16-pixel image window
/// (4 `lxv` at `img_off` from register `img_reg`), then 8 `xvf32ger[pp]`.
///
/// The Figure 8 accumulator grid: `acc[s]` for `s = 4*(x-half) + y-quarter`
/// covers filter rows `4*(s/4)..` and pixels `4*(s%4)..`.
fn emit_step(p: &mut Vec<Inst>, h_off: i32, img_reg: u8, img_off: i32, first: bool) {
    // x0 = vs32:33 (filters 0-3), x1 = vs34:35 (filters 4-7) — loaded as
    // two lxv each to keep DQ alignment (H columns are 32-byte entities)
    p.push(Inst::Lxv { xt: 32, ra: 3, dq: h_off });
    p.push(Inst::Lxv { xt: 33, ra: 3, dq: h_off + 16 });
    for j in 0..4u8 {
        p.push(Inst::Lxv { xt: 36 + j, ra: img_reg, dq: img_off + 16 * i32::from(j) });
    }
    let op = if first { AccOp::New } else { AccOp::PP };
    // Figure 8 issue order: acc 0,1,4,5,2,3,6,7
    for s in [0u8, 1, 4, 5, 2, 3, 6, 7] {
        let x = if s < 4 { 32 } else { 33 }; // filter half
        let y = 36 + (s % 4);
        p.push(Inst::Ger(Ger::new(GerKind::F32Ger, op, s, x, y)));
    }
}

/// Generate the `sconv_kernel_8x27x16` program (Figure 9).
///
/// Calling convention:
/// * `r3` — H, the 8×27 filter matrix, column-major (column `c` = 8 fp32 at
///   `r3 + 32c`; 27 columns = kernel positions × channels);
/// * `r6`, `r7`, `r8` — R, G, B channel base pointers; the kernel uses rows
///   `0..3` of each channel, a row being `row_stride` **bytes** long;
/// * `r10` — output C (the 8×16 block, Figure 4-style layout, 512 bytes).
///
/// Because `lxv` requires 16-byte-aligned displacements, the +4/+8 byte
/// shifts of equation (8) are realized by shift base registers `r11 = base+4`
/// and `r12 = base+8` (two `addi` per channel row — the indexed-load form
/// real code uses costs the same).
pub fn sconv_8x27x16_program(row_stride: i32) -> Vec<Inst> {
    assert!(row_stride % 16 == 0, "channel rows must stay 16-byte aligned");
    let mut p = Vec::with_capacity(27 * 14 + 60);
    let mut h_off = 0i32;
    let mut first = true;
    for ch_reg in [6u8, 7, 8] {
        for row in 0..3i32 {
            let row_off = row * row_stride;
            // shift registers for the +4 / +8 byte offsets of eq. (8)
            p.push(Inst::Addi { rt: 11, ra: ch_reg, si: row_off + 4 });
            p.push(Inst::Addi { rt: 12, ra: ch_reg, si: row_off + 8 });
            // shift 0 (from the channel register directly), then +4, +8
            emit_step(&mut p, h_off, ch_reg, row_off, first);
            first = false;
            h_off += 32;
            emit_step(&mut p, h_off, 11, 0, false);
            h_off += 32;
            emit_step(&mut p, h_off, 12, 0, false);
            h_off += 32;
        }
    }
    // epilogue: mma_store_acc(acc[s], C, 4s) — Figure 9 lines 55-62
    for s in 0..8u8 {
        p.push(Inst::XxMfAcc { acc: s });
        for r in 0..4u8 {
            p.push(Inst::Stxv { xs: s * 4 + r, ra: 10, dq: 64 * i32::from(s) + 16 * i32::from(r) });
        }
    }
    p.push(Inst::Blr);
    p
}

/// Run the SCONV kernel: `filters` is `8×3×3×3` (filter, channel, ky, kx),
/// `r`, `g`, `b` are channel images with `width ≥ 18` pixels per row and at
/// least 3 rows. Returns the 8×16 output block: filter `f` applied at
/// output pixels `0..16` of row 0.
pub fn run_sconv_8x27x16(
    filters: &[f32],
    r: &[f32],
    g: &[f32],
    b: &[f32],
    width: usize,
) -> Result<[[f32; 16]; 8], ExecError> {
    assert_eq!(filters.len(), 8 * 27);
    assert!(width >= 18, "need 16 outputs + 2 halo pixels");
    assert!(width % 4 == 0, "row stride must keep 16-byte alignment");
    for img in [r, g, b] {
        assert!(img.len() >= 3 * width);
    }
    let row_stride = (width * 4) as i32;

    // H layout: column c = 8 filter weights for (channel, ky, kx) position c,
    // where c = 9*channel + 3*ky + kx (the Figure 9 H+{0,8,16,...} walk).
    let hb = 0u64;
    let mut h = vec![0f32; 8 * 27];
    for f in 0..8 {
        for ch in 0..3 {
            for ky in 0..3 {
                for kx in 0..3 {
                    let c = 9 * ch + 3 * ky + kx;
                    h[c * 8 + f] = filters[f * 27 + ch * 9 + ky * 3 + kx];
                }
            }
        }
    }
    let rb = hb + (8 * 27 * 4) as u64;
    let img_bytes = (3 * width * 4) as u64;
    let gb = rb + img_bytes;
    let bb = gb + img_bytes;
    let cb = bb + img_bytes;
    let mut m = Machine::new((cb + 512) as usize);
    m.write_f32s(hb, &h);
    m.write_f32s(rb, &r[..3 * width]);
    m.write_f32s(gb, &g[..3 * width]);
    m.write_f32s(bb, &b[..3 * width]);
    m.gpr[3] = hb;
    m.gpr[6] = rb;
    m.gpr[7] = gb;
    m.gpr[8] = bb;
    m.gpr[10] = cb;
    let prog = sconv_8x27x16_program(row_stride);
    m.run(&prog, 4096)?;
    let raw = m.read_f32s(cb, 128);
    Ok(unpack_c8x16_f32(&raw))
}

/// Scalar reference: direct 3×3 convolution over 3 channels (oracle for
/// the kernel tests and benches).
pub fn sconv_reference(
    filters: &[f32],
    r: &[f32],
    g: &[f32],
    b: &[f32],
    width: usize,
    out_cols: usize,
) -> Vec<Vec<f32>> {
    let chans = [r, g, b];
    let mut out = vec![vec![0f32; out_cols]; 8];
    for f in 0..8 {
        for x in 0..out_cols {
            let mut acc = 0f32;
            for (ch, img) in chans.iter().enumerate() {
                for ky in 0..3 {
                    for kx in 0..3 {
                        acc += filters[f * 27 + ch * 9 + ky * 3 + kx] * img[ky * width + x + kx];
                    }
                }
            }
            out[f][x] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    #[test]
    fn instruction_mix_matches_fig9() {
        // 27 outer-product steps x 8 xvf32ger each = 216 ger instructions
        let prog = sconv_8x27x16_program(80);
        let gers: Vec<_> = prog
            .iter()
            .filter_map(|i| match i {
                Inst::Ger(g) => Some(*g),
                _ => None,
            })
            .collect();
        assert_eq!(gers.len(), 27 * 8);
        assert!(gers.iter().all(|g| g.kind == GerKind::F32Ger));
        // exactly the first 8 prime, the rest accumulate (Figure 9 line 13)
        assert!(gers[..8].iter().all(|g| g.op == AccOp::New));
        assert!(gers[8..].iter().all(|g| g.op == AccOp::PP));
        // 27 H-column loads x2 + 27 image loads x4 = 162 lxv
        let lxv = prog.iter().filter(|i| matches!(i, Inst::Lxv { .. })).count();
        assert_eq!(lxv, 27 * 6);
    }

    #[test]
    fn identity_filter_picks_center_pixel() {
        // filter 0: all zeros except center of channel R -> output = shifted R row 1
        let mut filters = vec![0f32; 8 * 27];
        filters[0 * 27 + 0 * 9 + 1 * 3 + 1] = 1.0; // f0, R, ky=1, kx=1
        let width = 20;
        let r: Vec<f32> = (0..3 * width).map(|i| i as f32).collect();
        let g = vec![0f32; 3 * width];
        let b = vec![0f32; 3 * width];
        let c = run_sconv_8x27x16(&filters, &r, &g, &b, width).unwrap();
        for x in 0..16 {
            assert_eq!(c[0][x], r[width + x + 1], "x={x}");
            for f in 1..8 {
                assert_eq!(c[f][x], 0.0);
            }
        }
    }

    #[test]
    fn kernel_vs_reference_property() {
        check("sconv == direct conv", 12, |rng: &mut Rng| {
            let width = 4 * rng.range(5, 12);
            let filters = rng.f32_vec(8 * 27);
            let r = rng.f32_vec(3 * width);
            let g = rng.f32_vec(3 * width);
            let b = rng.f32_vec(3 * width);
            let got = run_sconv_8x27x16(&filters, &r, &g, &b, width).unwrap();
            let expect = sconv_reference(&filters, &r, &g, &b, width, 16);
            for f in 0..8 {
                for x in 0..16 {
                    let (a, e) = (got[f][x], expect[f][x]);
                    assert!(
                        (a - e).abs() <= 1e-4 * e.abs().max(1.0),
                        "filter {f} pixel {x}: {a} vs {e}"
                    );
                }
            }
        });
    }

    #[test]
    fn multi_kernel_filters_independent() {
        // each filter only sees its own weights
        let width = 20;
        let mut filters = vec![0f32; 8 * 27];
        for f in 0..8 {
            filters[f * 27 + f % 27] = (f + 1) as f32;
        }
        let r: Vec<f32> = (0..3 * width).map(|i| (i % 7) as f32 - 3.0).collect();
        let g: Vec<f32> = (0..3 * width).map(|i| (i % 5) as f32).collect();
        let b: Vec<f32> = (0..3 * width).map(|i| (i % 3) as f32).collect();
        let got = run_sconv_8x27x16(&filters, &r, &g, &b, width).unwrap();
        let expect = sconv_reference(&filters, &r, &g, &b, width, 16);
        for f in 0..8 {
            for x in 0..16 {
                assert!((got[f][x] - expect[f][x]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn misaligned_row_stride_rejected() {
        let r = std::panic::catch_unwind(|| sconv_8x27x16_program(72));
        assert!(r.is_err(), "non-16-byte row stride must be rejected");
    }
}
