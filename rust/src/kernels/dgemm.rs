//! The paper's §V-A DGEMM kernel.
//!
//! [`dgemm_8xnx8_program`] generates the Figure 6 kernel
//! (`dgemm_kernel_8xNx8`): all eight accumulators form a virtual `8×8`
//! fp64 accumulator (Figure 4); each loop iteration performs an 8×8 outer
//! product of one column of X and one row of Yᵀ. Register assignment and
//! schedule replicate the g++ 11 object code of **Figure 7 byte-for-byte**
//! (`x0 → vs44:vs45`, `x1 → vs32:vs33`, `y → vs40..vs43`, accumulators in
//! GCC's allocation order `a4,a3,a5,a1,a6,a2,a7,a0`).
//!
//! [`run_dgemm_8xnx8`] executes the kernel on the functional machine;
//! [`dgemm_sim`] composes it into a full `M×N×K` matrix multiply
//! (all dimensions multiples of 8 — residual shapes are the prefixed-form
//! case study exercised in `gemm_rp` and the tests).

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};
use crate::isa::{ExecError, Machine};
use crate::kernels::pack::unpack_c8x8_f64;

/// GCC's accumulator allocation in Figure 7, in source order `acc[0..8]`:
/// source accumulator `s` lives in machine accumulator `GCC_ACC[s]`.
pub const GCC_ACC: [u8; 8] = [4, 5, 6, 7, 3, 1, 2, 0];

/// The (x-pair, y) operand of source accumulator `s`:
/// `x0 = vs44` (rows 0–3), `x1 = vs32` (rows 4–7), `y_j = vs40+j`.
fn operands(s: usize) -> (u8, u8) {
    let x = if s < 4 { 44 } else { 32 };
    let y = 40 + (s % 4) as u8;
    (x, y)
}

/// Figure 7 ger issue order, as source-accumulator indices: GCC interleaves
/// the two x pairs (`a4,a3,a5,a1,a6,a2,a7,a0`).
const FIG7_ORDER: [usize; 8] = [0, 4, 1, 5, 2, 6, 3, 7];

/// The Figure 7 loop body (17 instructions, 68 bytes).
pub fn fig7_loop_body() -> Vec<Inst> {
    let mut v = vec![
        Inst::Lxvp { xtp: 44, ra: 4, dq: 64 },
        Inst::Lxvp { xtp: 32, ra: 4, dq: 96 },
        Inst::Addi { rt: 5, ra: 5, si: 64 },
        Inst::Addi { rt: 4, ra: 4, si: 64 },
        Inst::Lxv { xt: 40, ra: 5, dq: 0 },
        Inst::Lxv { xt: 41, ra: 5, dq: 16 },
        Inst::Lxv { xt: 42, ra: 5, dq: 32 },
        Inst::Lxv { xt: 43, ra: 5, dq: 48 },
    ];
    for &s in &FIG7_ORDER {
        let (x, y) = operands(s);
        v.push(Inst::Ger(Ger::new(GerKind::F64Ger, AccOp::PP, GCC_ACC[s], x, y)));
    }
    v.push(Inst::Bdnz { bd: -64 });
    v
}

/// Generate the full `dgemm_kernel_8xNx8` program (Figure 6) for a given
/// inner dimension `n ≥ 1`.
///
/// Calling convention (paper Figure 6 / Power ABI):
/// * `r3` — output `A` (the 8×8 block, Figure 4 layout, 512 bytes);
/// * `r4` — packed X panel (8×n, column-major, 64 bytes per column);
/// * `r5` — packed Y panel (8×n, same layout);
/// The loop count is materialized with `li`/`mtctr`.
pub fn dgemm_8xnx8_program(n: usize) -> Vec<Inst> {
    assert!(n >= 1, "Figure 6 line 9: empty multiply handled by the caller");
    assert!(n <= i16::MAX as usize, "li immediate range");
    let mut p = Vec::with_capacity(32 + 17 + 48);
    // prologue: load column 0 / row 0 and prime with non-accumulating gers
    p.push(Inst::Lxvp { xtp: 44, ra: 4, dq: 0 });
    p.push(Inst::Lxvp { xtp: 32, ra: 4, dq: 32 });
    for j in 0..4u8 {
        p.push(Inst::Lxv { xt: 40 + j, ra: 5, dq: 16 * i32::from(j) });
    }
    for &s in &FIG7_ORDER {
        let (x, y) = operands(s);
        p.push(Inst::Ger(Ger::new(GerKind::F64Ger, AccOp::New, GCC_ACC[s], x, y)));
    }
    // main loop: the remaining n-1 outer products (Figure 7, byte-exact)
    if n > 1 {
        p.push(Inst::Addi { rt: 9, ra: 0, si: (n - 1) as i32 });
        p.push(Inst::Mtctr { rs: 9 });
        p.extend(fig7_loop_body());
    }
    // epilogue: Figure 6 lines 21-28 — xxmfacc + 4 stxv per accumulator,
    // source accumulator s stored at A + 64*s
    for s in 0..8usize {
        let acc = GCC_ACC[s];
        p.push(Inst::XxMfAcc { acc });
        for r in 0..4u8 {
            p.push(Inst::Stxv { xs: acc * 4 + r, ra: 3, dq: 64 * s as i32 + 16 * i32::from(r) });
        }
    }
    p.push(Inst::Blr);
    p
}

/// Number of dynamic instructions one `8×N×8` kernel call executes
/// (prologue + (n-1)·loop body + epilogue) — used by the cycle model's
/// trace cache.
pub fn dgemm_8xnx8_dynamic_insts(n: usize) -> u64 {
    let prologue = 14 + if n > 1 { 2 } else { 0 };
    let loop_insts = if n > 1 { 17 * (n as u64 - 1) } else { 0 };
    prologue as u64 + loop_insts + 41
}

/// Execute the Figure 6 kernel on the functional machine.
///
/// `x` and `y` are packed 8×n panels (column-major, see
/// [`crate::kernels::pack`]); returns the row-major 8×8 product
/// `C[i][j] = Σ_k x[i,k]·y[j,k]`.
pub fn run_dgemm_8xnx8(x: &[f64], y: &[f64], n: usize) -> Result<[[f64; 8]; 8], ExecError> {
    assert_eq!(x.len(), 8 * n);
    assert_eq!(y.len(), 8 * n);
    let xb = 0u64;
    let yb = (8 * n * 8) as u64;
    let ab = 2 * yb;
    let mut m = Machine::new(ab as usize + 512);
    m.write_f64s(xb, x);
    m.write_f64s(yb, y);
    m.gpr[3] = ab;
    m.gpr[4] = xb;
    m.gpr[5] = yb;
    let prog = dgemm_8xnx8_program(n);
    m.run(&prog, 64 + 20 * n as u64)?;
    let raw = m.read_f64s(ab, 64);
    Ok(unpack_c8x8_f64(&raw))
}

/// Full matrix multiply `C = A·B` on the simulated MMA machine.
///
/// `a` is `m×k` row-major, `b` is `k×n` row-major; `m`, `n` must be
/// multiples of 8. Packs panels once (the "other layers of DGEMM"), then
/// invokes the 8×k×8 kernel for every 8×8 block of C, reusing one machine
/// and one program. Returns `(C, stats)` where stats aggregate over all
/// kernel invocations.
pub fn dgemm_sim(
    a: &[f64],
    b: &[f64],
    m: usize,
    n: usize,
    k: usize,
) -> Result<(Vec<f64>, crate::isa::exec::ExecStats), ExecError> {
    assert!(m % 8 == 0 && n % 8 == 0, "m, n must be multiples of 8");
    assert!(k >= 1);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let (mb, nb) = (m / 8, n / 8);
    // pack X panels: panel bi, column kk = A[8bi..8bi+8, kk]
    let xpanels = 0u64;
    let panel_bytes = (8 * k * 8) as u64;
    let ypanels = xpanels + panel_bytes * mb as u64;
    let cbase = ypanels + panel_bytes * nb as u64;
    let mut mach = Machine::new((cbase + 512) as usize);
    let mut buf = vec![0f64; 8 * k];
    for bi in 0..mb {
        for kk in 0..k {
            for i in 0..8 {
                buf[kk * 8 + i] = a[(8 * bi + i) * k + kk];
            }
        }
        mach.write_f64s(xpanels + panel_bytes * bi as u64, &buf);
    }
    // pack Y panels: panel bj, column kk = B[kk, 8bj..8bj+8]
    for bj in 0..nb {
        for kk in 0..k {
            for j in 0..8 {
                buf[kk * 8 + j] = b[kk * n + 8 * bj + j];
            }
        }
        mach.write_f64s(ypanels + panel_bytes * bj as u64, &buf);
    }
    let prog = dgemm_8xnx8_program(k);
    let fuel = 64 + 20 * k as u64;
    let mut c = vec![0f64; m * n];
    for bi in 0..mb {
        for bj in 0..nb {
            mach.gpr[3] = cbase;
            mach.gpr[4] = xpanels + panel_bytes * bi as u64;
            mach.gpr[5] = ypanels + panel_bytes * bj as u64;
            mach.run(&prog, fuel)?;
            let raw = mach.read_f64s(cbase, 64);
            let blk = unpack_c8x8_f64(&raw);
            for i in 0..8 {
                for j in 0..8 {
                    c[(8 * bi + i) * n + 8 * bj + j] = blk[i][j];
                }
            }
        }
    }
    Ok((c, mach.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode_program;
    use crate::testkit::{assert_allclose, check, Rng};

    fn naive_gemm(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
        let mut c = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn fig7_loop_body_matches_paper_bytes() {
        let bytes = encode_program(&fig7_loop_body()).unwrap();
        let mut expect = Vec::new();
        for w in crate::isa::encode::FIG7_WORDS {
            expect.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(bytes, expect, "generated loop body must equal the Figure 7 listing");
    }

    #[test]
    fn fig7_instruction_mix() {
        // "Each column of X is loaded through two 32-byte load instructions
        // and each row of Y^T through four 16-byte loads; the accumulating
        // outer-product ... by 8 xvf64gerpp instructions" (§V-A.2)
        let body = fig7_loop_body();
        assert_eq!(body.len(), 17);
        assert_eq!(body.iter().filter(|i| matches!(i, Inst::Lxvp { .. })).count(), 2);
        assert_eq!(body.iter().filter(|i| matches!(i, Inst::Lxv { .. })).count(), 4);
        assert_eq!(body.iter().filter(|i| matches!(i, Inst::Addi { .. })).count(), 2);
        let gers: Vec<_> = body
            .iter()
            .filter_map(|i| match i {
                Inst::Ger(g) => Some(g),
                _ => None,
            })
            .collect();
        assert_eq!(gers.len(), 8);
        assert!(gers.iter().all(|g| g.kind == GerKind::F64Ger && g.op == AccOp::PP));
        // all 8 accumulators touched once
        let mut accs: Vec<u8> = gers.iter().map(|g| g.acc).collect();
        accs.sort();
        assert_eq!(accs, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn kernel_8x1x8() {
        let x: Vec<f64> = (0..8).map(|i| i as f64 + 1.0).collect();
        let y: Vec<f64> = (0..8).map(|i| (8 - i) as f64).collect();
        let c = run_dgemm_8xnx8(&x, &y, 1).unwrap();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(c[i][j], x[i] * y[j], "({i},{j})");
            }
        }
    }

    #[test]
    fn kernel_vs_naive_property() {
        check("dgemm 8xNx8 == naive", 30, |rng: &mut Rng| {
            let n = rng.range(1, 40);
            let x = rng.f64_vec(8 * n);
            let y = rng.f64_vec(8 * n);
            let c = run_dgemm_8xnx8(&x, &y, n).unwrap();
            for i in 0..8 {
                for j in 0..8 {
                    let expect: f64 = (0..n).map(|kk| x[kk * 8 + i] * y[kk * 8 + j]).sum();
                    assert!(
                        (c[i][j] - expect).abs() <= 1e-12 * expect.abs().max(1.0),
                        "({i},{j}): {} vs {expect}",
                        c[i][j]
                    );
                }
            }
        });
    }

    #[test]
    fn dgemm_sim_vs_naive() {
        check("dgemm_sim == naive", 8, |rng: &mut Rng| {
            let m = 8 * rng.range(1, 4);
            let n = 8 * rng.range(1, 4);
            let k = rng.range(1, 48);
            let a = rng.f64_vec(m * k);
            let b = rng.f64_vec(k * n);
            let (c, _) = dgemm_sim(&a, &b, m, n, k).unwrap();
            let expect = naive_gemm(&a, &b, m, n, k);
            assert_allclose(&c, &expect, 1e-12, 1e-14);
        });
    }

    #[test]
    fn dgemm_sim_flops_accounting() {
        let m = 16;
        let n = 16;
        let k = 32;
        let a = vec![1.0; m * k];
        let b = vec![1.0; k * n];
        let (c, stats) = dgemm_sim(&a, &b, m, n, k).unwrap();
        assert!(c.iter().all(|&v| v == k as f64));
        // 2*m*n*k flops exactly (every MAC through a ger)
        assert_eq!(stats.flops, (2 * m * n * k) as u64);
    }

    #[test]
    fn dynamic_instruction_count_matches() {
        for n in [1usize, 2, 5, 33] {
            let x = vec![0.5; 8 * n];
            let y = vec![0.25; 8 * n];
            let xb = 0u64;
            let yb = (8 * n * 8) as u64;
            let ab = 2 * yb;
            let mut m = Machine::new(ab as usize + 512);
            m.write_f64s(xb, &x);
            m.write_f64s(yb, &y);
            m.gpr[3] = ab;
            m.gpr[4] = xb;
            m.gpr[5] = yb;
            m.run(&dgemm_8xnx8_program(n), 1 << 20).unwrap();
            assert_eq!(m.stats.instructions, dgemm_8xnx8_dynamic_insts(n), "n={n}");
        }
    }
}
