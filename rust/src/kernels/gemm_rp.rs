//! Reduced-precision GEMM kernels — the "OpenBLAS MMA enablement" of §VIII
//! ("supports double, single and half (bf16) precision") plus the int16 /
//! int8 / int4 deep-learning paths of Table I.
//!
//! All kernels share one skeleton (the Figure 8 `8×16` virtual accumulator):
//! per step, one `4×rank`-packed X column pair (2 `lxv`) and four Y quarters
//! (4 `lxv`) feed 8 rank-k updates; a CTR loop walks the packed panels.
//! A step consumes `rank` values of the inner dimension (`rank` = 1 for
//! fp32, 2 for bf16/fp16/int16, 4 for int8, 8 for int4), so the reduced
//! precision kernels do 2–8× the MACs per instruction — the Table I
//! throughput scaling.
//!
//! The prefixed (masked) forms handle residual `k` (when `k % rank ≠ 0`)
//! via the product mask — the §II-C "residual loop iterations" use case.

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};
use crate::isa::types::{f32_to_bf16, f32_to_f16};
use crate::isa::{ExecError, Machine};
use crate::kernels::pack::{unpack_c8x16_f32, unpack_c8x16_i32};

/// Generate the `8×(steps·rank)×16` kernel program for any non-fp64 kind.
///
/// Calling convention: `r3` = output C (512 B, Figure 4 layout), `r4` =
/// packed X panel (32 B per step), `r5` = packed Y panel (64 B per step).
/// `tail_pmsk`, if given, adds one final *prefixed* step whose product mask
/// enables only the first `k % rank` products (residual handling, §II-C).
pub fn rp_gemm_program(kind: GerKind, steps: usize, tail_pmsk: Option<u8>) -> Vec<Inst> {
    rp_gemm_program_op(kind, steps, tail_pmsk, AccOp::PP)
}

/// [`rp_gemm_program`] with the accumulate op of the non-priming steps
/// chosen by the caller: `AccOp::PP` is the modulo chain every kind
/// supports; `AccOp::SPP` builds the **saturating** integer chain
/// (`xvi8ger4spp`, §II-B.2's "do not wrap around" accumulate). The first
/// step always primes with `AccOp::New` — the Machine rejects the op at
/// execute time if it is invalid for `kind`.
pub fn rp_gemm_program_op(
    kind: GerKind,
    steps: usize,
    tail_pmsk: Option<u8>,
    acc_op: AccOp,
) -> Vec<Inst> {
    assert_ne!(kind, GerKind::F64Ger, "fp64 uses the Figure 6 kernel");
    assert!(steps >= 1 || tail_pmsk.is_some());
    let mut p = Vec::new();
    let emit_loads = |p: &mut Vec<Inst>| {
        p.push(Inst::Lxv { xt: 32, ra: 4, dq: 0 });
        p.push(Inst::Lxv { xt: 33, ra: 4, dq: 16 });
        for j in 0..4u8 {
            p.push(Inst::Lxv { xt: 36 + j, ra: 5, dq: 16 * i32::from(j) });
        }
    };
    let emit_gers = |p: &mut Vec<Inst>, op: AccOp, pmsk: Option<u8>| {
        for s in [0u8, 1, 4, 5, 2, 3, 6, 7] {
            let x = if s < 4 { 32 } else { 33 };
            let y = 36 + (s % 4);
            let inst = match pmsk {
                None => Ger::new(kind, op, s, x, y),
                Some(pm) => Ger::prefixed(kind, op, s, x, y, 0xf, 0xf, pm),
            };
            p.push(Inst::Ger(inst));
        }
    };
    let bump = |p: &mut Vec<Inst>| {
        p.push(Inst::Addi { rt: 4, ra: 4, si: 32 });
        p.push(Inst::Addi { rt: 5, ra: 5, si: 64 });
    };

    if steps >= 1 {
        // prologue step primes the accumulators
        emit_loads(&mut p);
        emit_gers(&mut p, AccOp::New, None);
        bump(&mut p);
        if steps > 1 {
            p.push(Inst::Addi { rt: 9, ra: 0, si: (steps - 1) as i32 });
            p.push(Inst::Mtctr { rs: 9 });
            let top_len = p.len();
            emit_loads(&mut p);
            emit_gers(&mut p, acc_op, None);
            bump(&mut p);
            // all loop-body instructions are 4 bytes
            let body_bytes = 4 * (p.len() - top_len) as i32;
            p.push(Inst::Bdnz { bd: -body_bytes });
        }
    }
    if let Some(pm) = tail_pmsk {
        let op = if steps == 0 { AccOp::New } else { acc_op };
        emit_loads(&mut p);
        emit_gers(&mut p, op, Some(pm));
        bump(&mut p);
    }
    // epilogue: store the 8 accumulators
    for s in 0..8u8 {
        p.push(Inst::XxMfAcc { acc: s });
        for r in 0..4u8 {
            p.push(Inst::Stxv { xs: s * 4 + r, ra: 3, dq: 64 * i32::from(s) + 16 * i32::from(r) });
        }
    }
    p.push(Inst::Blr);
    p
}

// ---------------------------------------------------------------------------
// Packing: X is 8×k row-major, Y is 16×k row-major (so both panels feed
// X·Yᵀ). A step covers `rank` consecutive k values.
// ---------------------------------------------------------------------------

fn steps_of(k: usize, rank: usize) -> (usize, usize) {
    (k / rank, k % rank)
}

/// Pack X (8×k, row-major `x[i*k + kk]`) for a rank-`rank` kernel: per step,
/// two 16-byte vectors (rows 0–3, rows 4–7), element `(i, kl)` at packed
/// index `i*rank + kl`, padding the tail step with zeros.
fn pack_x<T: Copy + Default>(x: &[T], k: usize, rank: usize) -> Vec<T> {
    let nsteps = k.div_ceil(rank);
    let mut out = vec![T::default(); nsteps * 8 * rank];
    for (kk, _) in (0..k).enumerate() {
        let (step, kl) = (kk / rank, kk % rank);
        for i in 0..8 {
            let half = i / 4;
            let row = i % 4;
            out[step * 8 * rank + half * 4 * rank + row * rank + kl] = x[i * k + kk];
        }
    }
    out
}

/// Pack Y (16×k, row-major `y[j*k + kk]`): per step, four 16-byte vectors
/// (column quarters), element `(j, kl)` at `j*rank + kl` within its quarter.
fn pack_y<T: Copy + Default>(y: &[T], k: usize, rank: usize) -> Vec<T> {
    let nsteps = k.div_ceil(rank);
    let mut out = vec![T::default(); nsteps * 16 * rank];
    for kk in 0..k {
        let (step, kl) = (kk / rank, kk % rank);
        for j in 0..16 {
            let quarter = j / 4;
            let jj = j % 4;
            out[step * 16 * rank + quarter * 4 * rank + jj * rank + kl] = y[j * k + kk];
        }
    }
    out
}

fn tail_mask(rem: usize) -> Option<u8> {
    if rem == 0 {
        None
    } else {
        Some(((1u16 << rem) - 1) as u8)
    }
}

/// Shared driver: write packed panels, run, read the raw C block.
#[allow(clippy::too_many_arguments)]
fn run_rp<TX: Copy, TY: Copy>(
    kind: GerKind,
    xpacked: &[TX],
    ypacked: &[TY],
    k: usize,
    write_x: impl Fn(&mut Machine, u64, &[TX]),
    write_y: impl Fn(&mut Machine, u64, &[TY]),
    elem_x: usize,
    elem_y: usize,
    acc_op: AccOp,
) -> Result<Vec<u8>, ExecError> {
    let rank = kind.rank();
    let (steps, rem) = steps_of(k, rank);
    let xb = 0u64;
    let yb = xb + (xpacked.len() * elem_x).next_multiple_of(16) as u64;
    let cb = yb + (ypacked.len() * elem_y).next_multiple_of(16) as u64;
    let mut m = Machine::new((cb + 512) as usize);
    write_x(&mut m, xb, xpacked);
    write_y(&mut m, yb, ypacked);
    m.gpr[3] = cb;
    m.gpr[4] = xb;
    m.gpr[5] = yb;
    let prog = rp_gemm_program_op(kind, steps, tail_mask(rem), acc_op);
    m.run(&prog, 1024 + 32 * (steps as u64 + 2))?;
    Ok(m.mem[cb as usize..cb as usize + 512].to_vec())
}

fn c_as_f32(raw: &[u8]) -> [[f32; 16]; 8] {
    let vals: Vec<f32> =
        raw.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect();
    unpack_c8x16_f32(&vals)
}

fn c_as_i32(raw: &[u8]) -> [[i32; 16]; 8] {
    let vals: Vec<i32> =
        raw.chunks_exact(4).map(|b| i32::from_le_bytes(b.try_into().unwrap())).collect();
    unpack_c8x16_i32(&vals)
}

/// fp32 `8×k×16` GEMM (the Figure 8 datapath): `C[i][j] = Σ x[i,k]·y[j,k]`.
pub fn gemm_f32_8x16(x: &[f64], y: &[f64], k: usize) -> Result<[[f32; 16]; 8], ExecError> {
    assert_eq!(x.len(), 8 * k);
    assert_eq!(y.len(), 16 * k);
    let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
    let xp = pack_x(&xf, k, 1);
    let yp = pack_y(&yf, k, 1);
    let raw = run_rp(GerKind::F32Ger, &xp, &yp, k, |m, a, d| m.write_f32s(a, d), |m, a, d| m.write_f32s(a, d), 4, 4, AccOp::PP)?;
    Ok(c_as_f32(&raw))
}

/// bf16 inputs, fp32 accumulation (`xvbf16ger2`): inputs given as f32 and
/// rounded to bf16 exactly as the packing layer of a bf16 GEMM would.
pub fn gemm_bf16_8x16(x: &[f32], y: &[f32], k: usize) -> Result<[[f32; 16]; 8], ExecError> {
    assert_eq!(x.len(), 8 * k);
    assert_eq!(y.len(), 16 * k);
    let xh: Vec<u16> = x.iter().map(|&v| f32_to_bf16(v)).collect();
    let yh: Vec<u16> = y.iter().map(|&v| f32_to_bf16(v)).collect();
    let xp = pack_x(&xh, k, 2);
    let yp = pack_y(&yh, k, 2);
    let raw = run_rp(GerKind::Bf16Ger2, &xp, &yp, k, |m, a, d| m.write_u16s(a, d), |m, a, d| m.write_u16s(a, d), 2, 2, AccOp::PP)?;
    Ok(c_as_f32(&raw))
}

/// IEEE fp16 inputs, fp32 accumulation (`xvf16ger2`).
pub fn gemm_f16_8x16(x: &[f32], y: &[f32], k: usize) -> Result<[[f32; 16]; 8], ExecError> {
    assert_eq!(x.len(), 8 * k);
    assert_eq!(y.len(), 16 * k);
    let xh: Vec<u16> = x.iter().map(|&v| f32_to_f16(v)).collect();
    let yh: Vec<u16> = y.iter().map(|&v| f32_to_f16(v)).collect();
    let xp = pack_x(&xh, k, 2);
    let yp = pack_y(&yh, k, 2);
    let raw = run_rp(GerKind::F16Ger2, &xp, &yp, k, |m, a, d| m.write_u16s(a, d), |m, a, d| m.write_u16s(a, d), 2, 2, AccOp::PP)?;
    Ok(c_as_f32(&raw))
}

/// int16 inputs, int32 modulo accumulation (`xvi16ger2`).
pub fn gemm_i16_8x16(x: &[i16], y: &[i16], k: usize) -> Result<[[i32; 16]; 8], ExecError> {
    assert_eq!(x.len(), 8 * k);
    assert_eq!(y.len(), 16 * k);
    let xu: Vec<u16> = x.iter().map(|&v| v as u16).collect();
    let yu: Vec<u16> = y.iter().map(|&v| v as u16).collect();
    let xp = pack_x(&xu, k, 2);
    let yp = pack_y(&yu, k, 2);
    let raw = run_rp(GerKind::I16Ger2, &xp, &yp, k, |m, a, d| m.write_u16s(a, d), |m, a, d| m.write_u16s(a, d), 2, 2, AccOp::PP)?;
    Ok(c_as_i32(&raw))
}

/// int8 (signed X) × uint8 (unsigned Y) with int32 accumulation
/// (`xvi8ger4`, the §II-B.2 mixed-signedness deep-learning path).
pub fn gemm_i8_8x16(x: &[i8], y: &[u8], k: usize) -> Result<[[i32; 16]; 8], ExecError> {
    gemm_i8_8x16_op(x, y, k, AccOp::PP)
}

/// [`gemm_i8_8x16`] with the **saturating** accumulate chain
/// (`xvi8ger4` prime + `xvi8ger4spp` steps): each step's exact rank-4
/// sum folds into the i32 accumulator with clamping instead of
/// wrapping — the differential oracle for `I8Accum::Saturating` in
/// `blas::i8_gemm`.
pub fn gemm_i8_8x16_sat(x: &[i8], y: &[u8], k: usize) -> Result<[[i32; 16]; 8], ExecError> {
    gemm_i8_8x16_op(x, y, k, AccOp::SPP)
}

fn gemm_i8_8x16_op(
    x: &[i8],
    y: &[u8],
    k: usize,
    acc_op: AccOp,
) -> Result<[[i32; 16]; 8], ExecError> {
    assert_eq!(x.len(), 8 * k);
    assert_eq!(y.len(), 16 * k);
    let xb: Vec<u8> = x.iter().map(|&v| v as u8).collect();
    let xp = pack_x(&xb, k, 4);
    let yp = pack_y(y, k, 4);
    let w = |m: &mut Machine, a: u64, d: &[u8]| m.mem[a as usize..a as usize + d.len()].copy_from_slice(d);
    let raw = run_rp(GerKind::I8Ger4, &xp, &yp, k, w, w, 1, 1, acc_op)?;
    Ok(c_as_i32(&raw))
}

/// int4 × int4 with int32 accumulation (`xvi4ger8`): values must be in
/// [-8, 7]; packed two per byte.
pub fn gemm_i4_8x16(x: &[i32], y: &[i32], k: usize) -> Result<[[i32; 16]; 8], ExecError> {
    assert_eq!(x.len(), 8 * k);
    assert_eq!(y.len(), 16 * k);
    let xp = pack_x(x, k, 8);
    let yp = pack_y(y, k, 8);
    let to_nibbles = |vals: &[i32]| -> Vec<u8> {
        vals.chunks(2)
            .map(|p| crate::isa::types::int4_pack(p[0], *p.get(1).unwrap_or(&0)))
            .collect()
    };
    let (xn, yn) = (to_nibbles(&xp), to_nibbles(&yp));
    let w = |m: &mut Machine, a: u64, d: &[u8]| m.mem[a as usize..a as usize + d.len()].copy_from_slice(d);
    let raw = run_rp(GerKind::I4Ger8, &xn, &yn, k, w, w, 1, 1, AccOp::PP)?;
    Ok(c_as_i32(&raw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::types::{bf16_to_f32, f16_to_f32};
    use crate::testkit::{check, Rng};

    #[test]
    fn f32_kernel_vs_naive() {
        check("gemm f32 8x16", 15, |rng: &mut Rng| {
            let k = rng.range(1, 30);
            let x = rng.f64_vec(8 * k);
            let y = rng.f64_vec(16 * k);
            let c = gemm_f32_8x16(&x, &y, k).unwrap();
            for i in 0..8 {
                for j in 0..16 {
                    let e: f32 =
                        (0..k).map(|kk| (x[i * k + kk] as f32) * (y[j * k + kk] as f32)).sum();
                    assert!((c[i][j] - e).abs() <= 1e-4 * e.abs().max(1.0), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn bf16_kernel_vs_rounded_reference() {
        check("gemm bf16 8x16", 10, |rng: &mut Rng| {
            let k = rng.range(1, 24); // odd k exercises the masked tail
            let x = rng.f32_vec(8 * k);
            let y = rng.f32_vec(16 * k);
            let c = gemm_bf16_8x16(&x, &y, k).unwrap();
            for i in 0..8 {
                for j in 0..16 {
                    // reference: same bf16 rounding, f32 accumulate
                    let e: f32 = (0..k)
                        .map(|kk| {
                            bf16_to_f32(f32_to_bf16(x[i * k + kk]))
                                * bf16_to_f32(f32_to_bf16(y[j * k + kk]))
                        })
                        .sum();
                    assert!((c[i][j] - e).abs() <= 1e-3 * e.abs().max(1.0), "({i},{j}) {} {e}", c[i][j]);
                }
            }
        });
    }

    #[test]
    fn f16_kernel_vs_rounded_reference() {
        check("gemm f16 8x16", 8, |rng: &mut Rng| {
            let k = rng.range(1, 16);
            let x = rng.f32_vec(8 * k);
            let y = rng.f32_vec(16 * k);
            let c = gemm_f16_8x16(&x, &y, k).unwrap();
            for i in 0..8 {
                for j in 0..16 {
                    let e: f32 = (0..k)
                        .map(|kk| {
                            f16_to_f32(f32_to_f16(x[i * k + kk])) * f16_to_f32(f32_to_f16(y[j * k + kk]))
                        })
                        .sum();
                    assert!((c[i][j] - e).abs() <= 1e-3 * e.abs().max(1.0), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn i16_kernel_exact() {
        check("gemm i16 8x16", 10, |rng: &mut Rng| {
            let k = rng.range(1, 20);
            let x: Vec<i16> = (0..8 * k).map(|_| rng.irange(-3000, 3000) as i16).collect();
            let y: Vec<i16> = (0..16 * k).map(|_| rng.irange(-3000, 3000) as i16).collect();
            let c = gemm_i16_8x16(&x, &y, k).unwrap();
            for i in 0..8 {
                for j in 0..16 {
                    let e: i64 = (0..k)
                        .map(|kk| i64::from(x[i * k + kk]) * i64::from(y[j * k + kk]))
                        .sum();
                    assert_eq!(i64::from(c[i][j]), e, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn i8_kernel_exact_mixed_sign() {
        check("gemm i8xu8 8x16", 10, |rng: &mut Rng| {
            let k = rng.range(1, 24); // k not multiple of 4 exercises pmask tail
            let x: Vec<i8> = (0..8 * k).map(|_| rng.irange(-128, 127) as i8).collect();
            let y: Vec<u8> = (0..16 * k).map(|_| rng.irange(0, 255) as u8).collect();
            let c = gemm_i8_8x16(&x, &y, k).unwrap();
            for i in 0..8 {
                for j in 0..16 {
                    let e: i64 =
                        (0..k).map(|kk| i64::from(x[i * k + kk]) * i64::from(y[j * k + kk])).sum();
                    assert_eq!(i64::from(c[i][j]), e, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn i8_saturating_chain_clamps_instead_of_wrapping() {
        // pin every product at the most negative value: each rank-4 step
        // adds 4·(-128·255) = -130560 exactly, so enough steps drive the
        // exact sum past i32::MIN — where spp clamps and pp wraps
        let k = 4 * 16_500; // exact sum -2_154_240_000 < i32::MIN
        let x = vec![-128i8; 8 * k];
        let y = vec![255u8; 16 * k];
        let sat = gemm_i8_8x16_sat(&x, &y, k).unwrap();
        let wrap = gemm_i8_8x16(&x, &y, k).unwrap();
        assert!(sat.iter().flatten().all(|&v| v == i32::MIN));
        assert!(wrap.iter().flatten().all(|&v| v != i32::MIN));
    }

    #[test]
    fn i4_kernel_exact() {
        check("gemm i4 8x16", 8, |rng: &mut Rng| {
            let k = rng.range(1, 30); // tails of 1..7 exercise the 8-bit pmask
            let x: Vec<i32> = (0..8 * k).map(|_| rng.irange(-8, 7) as i32).collect();
            let y: Vec<i32> = (0..16 * k).map(|_| rng.irange(-8, 7) as i32).collect();
            let c = gemm_i4_8x16(&x, &y, k).unwrap();
            for i in 0..8 {
                for j in 0..16 {
                    let e: i64 =
                        (0..k).map(|kk| i64::from(x[i * k + kk]) * i64::from(y[j * k + kk])).sum();
                    assert_eq!(i64::from(c[i][j]), e, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn residual_tail_uses_prefixed_form() {
        // k=3 with rank-2 kind -> 1 full step + masked tail step
        let prog = rp_gemm_program(GerKind::Bf16Ger2, 1, Some(0b01));
        let prefixed: Vec<_> = prog
            .iter()
            .filter_map(|i| match i {
                Inst::Ger(g) if g.prefixed => Some(*g),
                _ => None,
            })
            .collect();
        assert_eq!(prefixed.len(), 8, "tail step is fully masked");
        assert!(prefixed.iter().all(|g| g.pmsk == 0b01));
    }

    #[test]
    fn throughput_scaling_macs_per_instruction() {
        // Table I: one xvi4ger8 does 4x the MACs of xvf32ger etc.
        assert_eq!(GerKind::I4Ger8.flops() / GerKind::F32Ger.flops(), 8);
        assert_eq!(GerKind::I8Ger4.flops() / GerKind::F32Ger.flops(), 4);
        assert_eq!(GerKind::Bf16Ger2.flops() / GerKind::F32Ger.flops(), 2);
    }
}
