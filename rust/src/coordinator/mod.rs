//! The serving coordinator — the §I "data-in-flight" scenario: "a system
//! processing data-in-flight is likely to be evaluating multiple distinct
//! models at once … Agility and flexibility of switching models, while
//! performing well, are important."
//!
//! Rust owns the event loop (and everything else on the request path —
//! python ran once, at AOT time):
//!
//! * a **router** dispatches each request to its model family (tabular
//!   classification / GEMM / convolution);
//! * a **continuous batcher** drains each shard's queue into a ladder of
//!   compiled batch buckets ([`CoordinatorConfig::buckets`], e.g.
//!   `m = 1/8/32`): whatever is pending when the largest bucket fills or
//!   the latency window ([`CoordinatorConfig::max_delay`]) expires
//!   executes in the **smallest bucket that covers it** — partial
//!   batches no longer pad all the way to one fixed compiled size, and
//!   a full queue executes at maximum GEMM utilization (the paper's §VI
//!   efficiency-vs-`m` curve, applied to serving). Output rows scatter
//!   back to their callers;
//! * **backpressure** comes from the bounded per-shard submission queues
//!   plus optional per-model-family policies
//!   ([`CoordinatorConfig::policies`]): in-flight caps and low-priority
//!   shedding keep one family from starving the batcher under mixed
//!   traffic;
//! * the executables run on **`shards` engine threads**
//!   ([`CoordinatorConfig::shards`]), each with its own bounded queue
//!   and its own engine instance; requests route per [`ShardRouting`] —
//!   by default a request's **model family hashes to a sticky shard**
//!   (every bucket of a family hashes as one name), so a family's
//!   compiled bucket plans and packed-panel buffers stay hot on one
//!   engine (round-robin by id stays available for
//!   single-model-dominated traffic). Backends may be thread-confined —
//!   each engine is constructed *inside* its thread via the factory, so
//!   no `Send` requirement leaks.
//!
//! ## Threading and ownership contract
//!
//! The request lifecycle is: caller thread → [`Coordinator::submit`]
//! (bounded per-shard channel) → **engine thread** (router + batcher) →
//! compiled model → per-request reply channel. Three rules keep this
//! sound:
//!
//! 1. **Engines are thread-confined.** The `engine_factory` runs once on
//!    each shard's engine thread and the resulting [`InferenceEngine`]
//!    never crosses a thread boundary afterwards; only the factory
//!    itself must be `Send + Sync`. Models may therefore use interior
//!    mutability freely (the plan backend's preallocated
//!    [`plan::ExecBuffers`](crate::runtime::plan::ExecBuffers)
//!    lock is uncontended by construction).
//! 2. **Data-parallel workers come from one shared pool.** The blocked
//!    GEMM behind the plan backend ([`crate::blas::block_gemm`]) fans
//!    its column-chunk loop out over the **persistent worker pool** of a
//!    [`Device`](crate::runtime::device::Device); the dispatch drains
//!    *inside* each `dot` (the engine thread participates and blocks
//!    until its chunks finish), so from the coordinator's point of view
//!    `run()` is still a synchronous call and shutdown ordering
//!    (`Msg::Shutdown` → flush → join) is unchanged. Because every shard
//!    draws from the same device pool, adding shards multiplies
//!    throughput without multiplying GEMM worker threads — shards cannot
//!    oversubscribe the core budget.
//! 3. **Responses are owned, requests are moved.** A request's payload
//!    moves into its shard's engine thread; the reply channel is the
//!    only route back. Nothing on the hot path is shared mutable state
//!    except the atomic [`CoordStats`] counters (shared by all shards)
//!    and the per-policy in-flight counters.
//!
//! ## Time
//!
//! Deadlines and latencies read a [`Clock`]: real time by default, or a
//! [`ManualTime`] handle tests advance explicitly — the deflaking hook
//! for deadline behavior on loaded CI runners (batching decisions become
//! deterministic functions of clock reads, not of scheduler timing).

use crate::error::Result;
use crate::metrics::{Counter, Histogram};
use crate::rt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Abstraction over the model runtime so the coordinator is unit-testable
/// without compiled artifacts.
pub trait InferenceEngine {
    /// Execute `model` on flat f32 inputs, returning the flat output.
    fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>>;

    /// Whether `model` is servable. The batcher resolves its bucket
    /// ladder through this at startup, so an engine that only loaded
    /// the largest compiled batch keeps the legacy pad-to-max behavior
    /// instead of erroring on smaller buckets. Defaults to `true`
    /// (mock engines serve any batch size).
    fn has_model(&self, _model: &str) -> bool {
        true
    }
}

impl InferenceEngine for crate::runtime::Runtime {
    fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        self.execute(model, inputs)
    }

    fn has_model(&self, model: &str) -> bool {
        self.meta(model).is_some()
    }
}

/// A request payload: one of the model families served.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Tabular features for the batched MLP classifier.
    Classify { features: Vec<f32> },
    /// A 128×128 GEMM tile (`model` = `gemm_f32` or `gemm_bf16`).
    Gemm { model: String, x: Vec<f32>, y: Vec<f32> },
    /// 8 filter banks over a 3-channel image (the SCONV service).
    Conv { filters: Vec<f32>, image: Vec<f32> },
    /// One complex signal row for the batched DFT family
    /// ([`CoordinatorConfig::dft_n`] points, split re/im). The response
    /// carries `2·dft_n` values: the spectrum's real bins followed by
    /// its imaginary bins.
    Dft { re: Vec<f32>, im: Vec<f32> },
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<Vec<f32>, String>,
    /// Submit → reply latency.
    pub latency: Duration,
}

/// RAII in-flight token for a policy-tracked model family: incremented
/// at submit, decremented when the request is dropped — which happens on
/// *every* exit path (reply sent, batch failed, engine dead), so the
/// counter cannot leak.
struct InflightGuard(Arc<AtomicU64>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

struct Request {
    id: u64,
    payload: Payload,
    submitted: Instant,
    reply: rt::Sender<Response>,
    /// Held for the request's lifetime when its family has a policy.
    _inflight: Option<InflightGuard>,
}

enum Msg {
    Req(Box<Request>),
    Shutdown,
}

/// How requests map to engine shards (the ROADMAP "shard-aware routing
/// / model affinity" policy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardRouting {
    /// **Sticky (the default):** hash the request's *model family* to a
    /// shard, so a family always lands on the same engine — its
    /// compiled bucket plans, arenas, and packed-panel scratch stay hot
    /// in that engine's caches instead of ping-ponging across shards.
    /// Every classify request hashes as one name
    /// ([`CoordinatorConfig::mlp_model`]) regardless of which bucket it
    /// ends up executing in. The hash (FNV-1a) is deterministic across
    /// runs and processes.
    ModelSticky,
    /// Spread requests round-robin by request id — even load regardless
    /// of model mix (the pre-affinity behavior; the right choice when
    /// traffic is dominated by a single model family, where stickiness
    /// would funnel everything through one shard).
    RoundRobin,
}

/// Request priority class for [`ModelPolicy`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Admitted whenever the target shard queue has space.
    Normal,
    /// Shed early: [`Coordinator::try_submit`] rejects the request once
    /// the target shard's queue is at least half full, keeping headroom
    /// for normal-priority traffic under load.
    Low,
}

/// Per-model-family admission policy (the ROADMAP "per-model
/// queue-depth caps / priorities" item): applied by
/// [`Coordinator::try_submit`] — the backpressure interface. The
/// blocking [`Coordinator::submit`] records in-flight counts but never
/// rejects (callers who block have opted out of shedding).
#[derive(Clone, Debug)]
pub struct ModelPolicy {
    /// Family key: [`CoordinatorConfig::mlp_model`] for classify
    /// traffic, the exact model name for direct-dispatch families.
    pub model: String,
    /// Maximum requests of this family in flight (submitted, not yet
    /// replied) across all shards; `0` = unlimited.
    pub max_inflight: usize,
    pub priority: Priority,
}

impl ModelPolicy {
    /// Cap a family's in-flight depth at `max_inflight`.
    pub fn capped(model: &str, max_inflight: usize) -> ModelPolicy {
        ModelPolicy { model: model.to_string(), max_inflight, priority: Priority::Normal }
    }

    /// Mark a family low-priority (shed when its shard queue is ≥ half
    /// full), with no in-flight cap.
    pub fn low_priority(model: &str) -> ModelPolicy {
        ModelPolicy { model: model.to_string(), max_inflight: 0, priority: Priority::Low }
    }
}

/// Time source for batching deadlines and latency accounting.
/// [`Clock::default`] reads `Instant::now`; [`Clock::manual`] returns a
/// clock frozen at construction plus a [`ManualTime`] handle whose
/// `advance` moves it forward deterministically — timing-sensitive tests
/// drive the batcher without sleeping.
#[derive(Clone, Debug, Default)]
pub struct Clock(Option<Arc<ManualTime>>);

/// Shared handle behind a manual [`Clock`].
#[derive(Debug)]
pub struct ManualTime {
    base: Instant,
    offset_us: AtomicU64,
}

impl ManualTime {
    /// Move the clock forward by `d` (saturating at microsecond grain).
    pub fn advance(&self, d: Duration) {
        self.offset_us.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }
}

impl Clock {
    /// The real clock (`Instant::now`).
    pub fn real() -> Clock {
        Clock(None)
    }

    /// A manual clock plus the handle that advances it.
    pub fn manual() -> (Clock, Arc<ManualTime>) {
        let m = Arc::new(ManualTime { base: Instant::now(), offset_us: AtomicU64::new(0) });
        (Clock(Some(m.clone())), m)
    }

    /// Current time on this clock.
    pub fn now(&self) -> Instant {
        match &self.0 {
            None => Instant::now(),
            Some(m) => m.base + Duration::from_micros(m.offset_us.load(Ordering::Relaxed)),
        }
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Compiled MLP batch-bucket ladder (each entry must match a loaded
    /// artifact, e.g. `mlp_b8`). The batcher executes each window in
    /// the smallest bucket ≥ the pending row count; see
    /// [`CoordinatorConfig::ladder`] for normalization.
    pub buckets: Vec<usize>,
    /// The batching window: maximum time the batcher holds a partial
    /// batch before flushing it at the deadline.
    pub max_delay: Duration,
    /// Bounded submission queue depth **per shard** (backpressure).
    pub queue_cap: usize,
    /// Number of engine threads (shards). Each shard runs its own engine
    /// behind its own bounded queue; requests are routed per
    /// [`CoordinatorConfig::routing`]. Engines built over
    /// [`Runtime`](crate::runtime::Runtime)s that share a
    /// [`Device`](crate::runtime::device::Device) draw their GEMM
    /// workers from the one shared pool, so shards scale request
    /// concurrency without oversubscribing cores. `0` is treated as `1`.
    pub shards: usize,
    /// Request→shard policy: sticky model-affinity hashing by default,
    /// [`ShardRouting::RoundRobin`] to keep the legacy even spread.
    pub routing: ShardRouting,
    /// Per-model-family admission policies (in-flight caps, priority
    /// shedding); empty = admit everything the queues accept.
    pub policies: Vec<ModelPolicy>,
    /// Time source for deadlines and latency (tests inject
    /// [`Clock::manual`]; the default reads real time).
    pub clock: Clock,
    /// MLP feature/class dims (must match `python/compile/model.py`).
    pub features: usize,
    pub classes: usize,
    pub hidden: usize,
    /// DFT length of the second served family (must match
    /// `python/compile/model.py::DFT_N`; one request row = one
    /// `dft_n`-point transform). The DFT family batches on the same
    /// bucket ladder as classify, resolved against the engine's loaded
    /// `dft_b{b}` plans.
    pub dft_n: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            buckets: vec![1, 8, 32],
            max_delay: Duration::from_millis(2),
            queue_cap: 1024,
            shards: 1,
            routing: ShardRouting::ModelSticky,
            policies: Vec::new(),
            clock: Clock::default(),
            features: 64,
            classes: 32,
            hidden: 128,
            dft_n: 16,
        }
    }
}

impl CoordinatorConfig {
    /// The normalized bucket ladder: sorted ascending, deduplicated,
    /// zeros dropped. An empty `buckets` falls back to `[32]` (the
    /// legacy fixed batch size, matching the `mlp_b32` AOT fixture).
    pub fn ladder(&self) -> Vec<usize> {
        let mut l: Vec<usize> = self.buckets.iter().copied().filter(|&b| b > 0).collect();
        l.sort_unstable();
        l.dedup();
        if l.is_empty() {
            l.push(32);
        }
        l
    }

    /// Largest ladder bucket — the window size the batcher fills to.
    pub fn max_bucket(&self) -> usize {
        *self.ladder().last().unwrap()
    }

    /// The classify family's canonical model name (the largest bucket's
    /// plan). This is what sticky routing hashes for *every* classify
    /// request, so a family's whole bucket ladder pins to one shard.
    pub fn mlp_model(&self) -> String {
        self.mlp_model_for(self.max_bucket())
    }

    /// The compiled model name of one batch bucket.
    pub fn mlp_model_for(&self, bucket: usize) -> String {
        format!("mlp_b{bucket}")
    }

    /// The DFT family's canonical model name (the largest bucket's
    /// plan) — what sticky routing hashes for every [`Payload::Dft`]
    /// and what a [`ModelPolicy`] keys the family by.
    pub fn dft_model(&self) -> String {
        self.dft_model_for(self.max_bucket())
    }

    /// The compiled DFT model name of one batch bucket.
    pub fn dft_model_for(&self, bucket: usize) -> String {
        format!("dft_b{bucket}")
    }
}

/// Why a batch left the batcher — each flush increments exactly one
/// per-bucket reason counter in [`BucketStat`].
enum FlushWhy {
    /// Pending rows reached the largest bucket.
    Full,
    /// The oldest pending request hit the latency window.
    Deadline,
    /// Coordinator shutdown drained the remainder.
    Shutdown,
}

/// Per-bucket batching statistics: how often each compiled bucket
/// executed, why, and at what occupancy.
#[derive(Debug)]
pub struct BucketStat {
    /// The compiled batch size this row tracks.
    pub bucket: usize,
    /// Flushes triggered by the window filling to the largest bucket.
    pub full: Counter,
    /// Flushes forced by the latency deadline.
    pub deadline: Counter,
    /// Flushes during shutdown drain.
    pub shutdown: Counter,
    /// Real (unpadded) rows executed in this bucket.
    pub rows: Counter,
}

impl BucketStat {
    fn new(bucket: usize) -> BucketStat {
        BucketStat {
            bucket,
            full: Counter::new(),
            deadline: Counter::new(),
            shutdown: Counter::new(),
            rows: Counter::new(),
        }
    }

    /// Total executions of this bucket.
    pub fn flushes(&self) -> u64 {
        self.full.get() + self.deadline.get() + self.shutdown.get()
    }

    /// Mean fraction of the bucket's rows that were real requests
    /// (1.0 = no padding).
    pub fn occupancy(&self) -> f64 {
        let f = self.flushes();
        if f == 0 {
            0.0
        } else {
            self.rows.get() as f64 / (f * self.bucket as u64) as f64
        }
    }
}

/// Shared serving statistics.
#[derive(Default)]
pub struct CoordStats {
    pub received: Counter,
    pub completed: Counter,
    pub failed: Counter,
    /// Backpressure rejections (target shard queue full) from
    /// [`Coordinator::try_submit`].
    pub rejected: Counter,
    /// Policy rejections (in-flight cap hit, or low-priority shed) from
    /// [`Coordinator::try_submit`]; disjoint from `rejected`.
    pub throttled: Counter,
    pub batches: Counter,
    /// Sum of batch occupancies (completed classify requests).
    pub batched_requests: Counter,
    pub latency: Histogram,
    /// Per-family latency slices of [`CoordStats::latency`]: the batched
    /// classify (MLP) family.
    pub latency_mlp: Histogram,
    /// The batched DFT transform family.
    pub latency_dft: Histogram,
    /// Unbatched direct requests ([`Payload::Gemm`] / [`Payload::Conv`]).
    pub latency_direct: Histogram,
    /// One row per ladder bucket (ascending), shared by all shards.
    pub buckets: Vec<BucketStat>,
    /// The DFT family's per-bucket rows (same ladder, batched in its
    /// own window — a DFT flush never mixes with a classify flush).
    pub dft_buckets: Vec<BucketStat>,
}

impl CoordStats {
    fn for_buckets(ladder: &[usize]) -> CoordStats {
        CoordStats {
            buckets: ladder.iter().map(|&b| BucketStat::new(b)).collect(),
            dft_buckets: ladder.iter().map(|&b| BucketStat::new(b)).collect(),
            ..Default::default()
        }
    }

    /// The stats row of one ladder bucket.
    pub fn bucket(&self, bucket: usize) -> Option<&BucketStat> {
        self.buckets.iter().find(|s| s.bucket == bucket)
    }

    /// The DFT family's stats row of one ladder bucket.
    pub fn dft_bucket(&self, bucket: usize) -> Option<&BucketStat> {
        self.dft_buckets.iter().find(|s| s.bucket == bucket)
    }

    /// Mean rows per executed MLP batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batched_requests.get() as f64 / b as f64
        }
    }
}

/// Per-policy shared state: the policy plus its cross-shard in-flight
/// counter and its own throttle count (the per-family slice of
/// [`CoordStats::throttled`], readable via
/// [`Coordinator::throttled_for`]).
struct PolicyState {
    policy: ModelPolicy,
    inflight: Arc<AtomicU64>,
    throttled: Counter,
}

/// Handle to a running coordinator (one submission queue + engine
/// thread per shard; requests route per [`ShardRouting`] — sticky
/// model-family hashing by default, round-robin by request id on
/// demand).
pub struct Coordinator {
    txs: Vec<rt::Sender<Msg>>,
    engine_threads: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    routing: ShardRouting,
    /// The classify family name (what a `Classify` hashes as).
    mlp_model: String,
    /// The DFT family name (what a `Dft` hashes as).
    dft_model: String,
    queue_cap: usize,
    policies: Vec<PolicyState>,
    clock: Clock,
    pub stats: Arc<CoordStats>,
}

/// The MLP weights the service hosts. Deterministic (same formula as the
/// AOT expected-output fixtures) so end-to-end numerics are checkable.
#[derive(Clone)]
pub struct MlpWeights {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl MlpWeights {
    /// The weights `aot.py` baked expectations for (salts 2..=5).
    pub fn deterministic(cfg: &CoordinatorConfig) -> Self {
        use crate::runtime::det_input;
        MlpWeights {
            w1: det_input(cfg.features * cfg.hidden, 2),
            b1: det_input(cfg.hidden, 3),
            w2: det_input(cfg.hidden * cfg.classes, 4),
            b2: det_input(cfg.classes, 5),
        }
    }
}

impl Coordinator {
    /// Start the coordinator with [`CoordinatorConfig::shards`] engine
    /// threads. `engine_factory` runs once *on each shard's engine
    /// thread* (thread-confined backends never cross threads) and
    /// receives the shard index; it must be `Sync` because all shards
    /// share it. For a single-shard coordinator the factory is called
    /// exactly once, preserving the legacy behavior.
    pub fn start<E, F>(cfg: CoordinatorConfig, weights: MlpWeights, engine_factory: F) -> Self
    where
        E: InferenceEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let routing = cfg.routing;
        let mlp_model = cfg.mlp_model();
        let dft_model = cfg.dft_model();
        let stats = Arc::new(CoordStats::for_buckets(&cfg.ladder()));
        let policies: Vec<PolicyState> = cfg
            .policies
            .iter()
            .map(|p| PolicyState {
                policy: p.clone(),
                inflight: Arc::new(AtomicU64::new(0)),
                throttled: Counter::new(),
            })
            .collect();
        let factory = Arc::new(engine_factory);
        let mut txs = Vec::with_capacity(shards);
        let mut engine_threads = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = rt::bounded::<Msg>(cfg.queue_cap);
            let fac = factory.clone();
            let cfg2 = cfg.clone();
            let weights2 = weights.clone();
            let stats2 = stats.clone();
            let handle = std::thread::Builder::new()
                .name(format!("mma-engine-{shard}"))
                .spawn(move || engine_loop(cfg2, weights2, move || (*fac)(shard), rx, stats2))
                .expect("spawn engine thread");
            txs.push(tx);
            engine_threads.push(handle);
        }
        Coordinator {
            txs,
            engine_threads,
            next_id: AtomicU64::new(1),
            routing,
            mlp_model,
            dft_model,
            queue_cap: cfg.queue_cap,
            policies,
            clock: cfg.clock,
            stats,
        }
    }

    /// Number of engine shards.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// The model family a payload belongs to — what the sticky router
    /// hashes and what [`ModelPolicy`] keys match. Classify requests
    /// all map to [`CoordinatorConfig::mlp_model`] regardless of the
    /// bucket they execute in, so a family's whole ladder shares one
    /// shard and one policy.
    fn family_of<'a>(&'a self, payload: &'a Payload) -> &'a str {
        match payload {
            Payload::Classify { .. } => &self.mlp_model,
            Payload::Gemm { model, .. } => model,
            Payload::Conv { .. } => "conv2d_k3",
            Payload::Dft { .. } => &self.dft_model,
        }
    }

    /// Per-family policy throttle count (in-flight cap hits plus
    /// low-priority sheds, the family's slice of
    /// [`CoordStats::throttled`]); `None` when no policy tracks `model`.
    pub fn throttled_for(&self, model: &str) -> Option<u64> {
        self.policies.iter().find(|p| p.policy.model == model).map(|p| p.throttled.get())
    }

    /// The shard index a request routes to, per the configured policy.
    /// The sticky hash is the crate-wide deterministic FNV-1a
    /// ([`rt::fnv1a`]) — never `DefaultHasher`, whose algorithm is
    /// unspecified — so the shard a model lands on is stable across
    /// runs, processes, and toolchains.
    fn shard_index(&self, id: u64, payload: &Payload) -> usize {
        match self.routing {
            ShardRouting::RoundRobin => (id as usize) % self.txs.len(),
            ShardRouting::ModelSticky => {
                (rt::fnv1a(self.family_of(payload).as_bytes()) as usize) % self.txs.len()
            }
        }
    }

    /// Acquire the family's in-flight token (when a policy tracks it).
    fn inflight_token(&self, payload: &Payload) -> Option<InflightGuard> {
        let family = self.family_of(payload);
        self.policies.iter().find(|p| p.policy.model == family).map(|p| {
            p.inflight.fetch_add(1, Ordering::Relaxed);
            InflightGuard(p.inflight.clone())
        })
    }

    /// Submit a request; returns a receiver for the response. Fails fast
    /// (`Err(id)`) when the target shard's queue is full (`rejected`) or
    /// the family's [`ModelPolicy`] denies admission (`throttled`) —
    /// the backpressure signals.
    pub fn try_submit(&self, payload: Payload) -> Result<(u64, rt::Receiver<Response>), u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard_index(id, &payload);
        self.stats.received.inc();
        if let Some(p) = self.policies.iter().find(|p| p.policy.model == self.family_of(&payload))
        {
            let cap = p.policy.max_inflight as u64;
            if cap > 0 && p.inflight.load(Ordering::Relaxed) >= cap {
                self.stats.throttled.inc();
                p.throttled.inc();
                return Err(id);
            }
            if p.policy.priority == Priority::Low
                && self.queue_cap > 0
                && self.txs[shard].len() * 2 >= self.queue_cap
            {
                self.stats.throttled.inc();
                p.throttled.inc();
                return Err(id);
            }
        }
        let token = self.inflight_token(&payload);
        let (rtx, rrx) = rt::bounded(1);
        let req = Box::new(Request {
            id,
            payload,
            submitted: self.clock.now(),
            reply: rtx,
            _inflight: token,
        });
        match self.txs[shard].try_send(Msg::Req(req)) {
            Ok(()) => Ok((id, rrx)),
            Err(_) => {
                self.stats.rejected.inc();
                Err(id)
            }
        }
    }

    /// Blocking submit (waits for queue space on the target shard).
    /// Policies are recorded but never enforced here — a blocking
    /// caller has opted out of shedding.
    pub fn submit(&self, payload: Payload) -> (u64, rt::Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard_index(id, &payload);
        self.stats.received.inc();
        let token = self.inflight_token(&payload);
        let (rtx, rrx) = rt::bounded(1);
        let req = Box::new(Request {
            id,
            payload,
            submitted: self.clock.now(),
            reply: rtx,
            _inflight: token,
        });
        self.txs[shard].send(Msg::Req(req)).ok();
        (id, rrx)
    }

    /// Drain and stop every engine shard.
    pub fn shutdown(mut self) -> Arc<CoordStats> {
        for tx in &self.txs {
            tx.send(Msg::Shutdown).ok();
        }
        for h in self.engine_threads.drain(..) {
            h.join().expect("engine thread panicked");
        }
        self.stats.clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if !self.engine_threads.is_empty() {
            for tx in &self.txs {
                tx.send(Msg::Shutdown).ok();
            }
            for h in self.engine_threads.drain(..) {
                let _ = h.join();
            }
        }
    }
}

fn engine_loop<E, F>(
    cfg: CoordinatorConfig,
    weights: MlpWeights,
    factory: F,
    rx: rt::Receiver<Msg>,
    stats: Arc<CoordStats>,
) where
    E: InferenceEngine,
    F: FnOnce() -> Result<E>,
{
    let clock = cfg.clock.clone();
    let mut engine = match factory() {
        Ok(e) => e,
        Err(e) => {
            // fail every request with the construction error
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Req(req) => {
                        stats.failed.inc();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            result: Err(format!("engine init failed: {e}")),
                            latency: clock.now().saturating_duration_since(req.submitted),
                        });
                    }
                    Msg::Shutdown => break,
                }
            }
            return;
        }
    };
    // Resolve the usable ladder against the engine: buckets whose
    // compiled plan the engine actually loaded. An engine that only
    // loaded the largest bucket (e.g. load_all over the fixture set)
    // degrades to the legacy pad-to-max batcher.
    let ladder: Vec<usize> = {
        let mut l = cfg.ladder();
        l.retain(|&b| engine.has_model(&cfg.mlp_model_for(b)));
        if l.is_empty() {
            vec![cfg.max_bucket()]
        } else {
            l
        }
    };
    let max_bucket = *ladder.last().unwrap();
    let mut pending: Vec<Box<Request>> = Vec::with_capacity(max_bucket);
    // The DFT family batches on the same configured ladder but in its
    // own window, resolved against the engine's loaded dft_b{b} plans —
    // a flush never mixes families (the two models pack different
    // panels), and an engine without small DFT buckets degrades to
    // pad-to-max exactly like classify.
    let dft_ladder: Vec<usize> = {
        let mut l = cfg.ladder();
        l.retain(|&b| engine.has_model(&cfg.dft_model_for(b)));
        if l.is_empty() {
            vec![cfg.max_bucket()]
        } else {
            l
        }
    };
    let dft_max = *dft_ladder.last().unwrap();
    let mut dft_pending: Vec<Box<Request>> = Vec::with_capacity(dft_max);

    // Execute the pending window in the smallest bucket that covers it,
    // pad the tail, scatter output rows back per request.
    let flush =
        |engine: &mut E, pending: &mut Vec<Box<Request>>, stats: &CoordStats, why: FlushWhy| {
            if pending.is_empty() {
                return;
            }
            let rows = pending.len();
            let bucket = ladder.iter().copied().find(|&b| b >= rows).unwrap_or(max_bucket);
            let model = cfg.mlp_model_for(bucket);
            let mut xbatch = vec![0f32; bucket * cfg.features];
            for (r, req) in pending.iter().enumerate() {
                if let Payload::Classify { features } = &req.payload {
                    xbatch[r * cfg.features..(r + 1) * cfg.features].copy_from_slice(features);
                }
            }
            let result = engine
                .run(&model, &[&xbatch, &weights.w1, &weights.b1, &weights.w2, &weights.b2])
                .and_then(|out| {
                    if out.len() < rows * cfg.classes {
                        crate::bail!(
                            "{model}: engine returned {} values for {rows} rows of {} classes",
                            out.len(),
                            cfg.classes
                        );
                    }
                    Ok(out)
                });
            stats.batches.inc();
            stats.batched_requests.add(rows as u64);
            if let Some(bs) = stats.bucket(bucket) {
                match why {
                    FlushWhy::Full => bs.full.inc(),
                    FlushWhy::Deadline => bs.deadline.inc(),
                    FlushWhy::Shutdown => bs.shutdown.inc(),
                }
                bs.rows.add(rows as u64);
            }
            match result {
                Ok(out) => {
                    for (r, req) in pending.drain(..).enumerate() {
                        let row = out[r * cfg.classes..(r + 1) * cfg.classes].to_vec();
                        let latency = clock.now().saturating_duration_since(req.submitted);
                        stats.completed.inc();
                        stats.latency.record(latency);
                        stats.latency_mlp.record(latency);
                        let _ =
                            req.reply.send(Response { id: req.id, result: Ok(row), latency });
                    }
                }
                Err(e) => {
                    for req in pending.drain(..) {
                        stats.failed.inc();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            result: Err(format!("batch failed: {e}")),
                            latency: clock.now().saturating_duration_since(req.submitted),
                        });
                    }
                }
            }
        };

    // Execute the pending DFT window in its smallest covering bucket:
    // one engine call on the batched split re/im planes, then each
    // request's spectrum row scatters back as its yr bins followed by
    // its yi bins (output rows r and bucket+r of the stacked [2b,n]
    // result).
    let flush_dft =
        |engine: &mut E, pending: &mut Vec<Box<Request>>, stats: &CoordStats, why: FlushWhy| {
            if pending.is_empty() {
                return;
            }
            let rows = pending.len();
            let bucket = dft_ladder.iter().copied().find(|&b| b >= rows).unwrap_or(dft_max);
            let model = cfg.dft_model_for(bucket);
            let n = cfg.dft_n;
            let mut xr = vec![0f32; bucket * n];
            let mut xi = vec![0f32; bucket * n];
            for (r, req) in pending.iter().enumerate() {
                if let Payload::Dft { re, im } = &req.payload {
                    xr[r * n..(r + 1) * n].copy_from_slice(re);
                    xi[r * n..(r + 1) * n].copy_from_slice(im);
                }
            }
            let result = engine.run(&model, &[&xr, &xi]).and_then(|out| {
                if out.len() < (bucket + rows) * n {
                    crate::bail!(
                        "{model}: engine returned {} values for {rows} rows of {n} bins",
                        out.len()
                    );
                }
                Ok(out)
            });
            if let Some(bs) = stats.dft_bucket(bucket) {
                match why {
                    FlushWhy::Full => bs.full.inc(),
                    FlushWhy::Deadline => bs.deadline.inc(),
                    FlushWhy::Shutdown => bs.shutdown.inc(),
                }
                bs.rows.add(rows as u64);
            }
            match result {
                Ok(out) => {
                    for (r, req) in pending.drain(..).enumerate() {
                        let mut row = Vec::with_capacity(2 * n);
                        row.extend_from_slice(&out[r * n..(r + 1) * n]);
                        row.extend_from_slice(&out[(bucket + r) * n..(bucket + r + 1) * n]);
                        let latency = clock.now().saturating_duration_since(req.submitted);
                        stats.completed.inc();
                        stats.latency.record(latency);
                        stats.latency_dft.record(latency);
                        let _ =
                            req.reply.send(Response { id: req.id, result: Ok(row), latency });
                    }
                }
                Err(e) => {
                    for req in pending.drain(..) {
                        stats.failed.inc();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            result: Err(format!("batch failed: {e}")),
                            latency: clock.now().saturating_duration_since(req.submitted),
                        });
                    }
                }
            }
        };

    // Route one request: classify joins the batching window, DFT joins
    // its own window, GEMM/conv dispatch directly.
    let process = |engine: &mut E,
                   pending: &mut Vec<Box<Request>>,
                   dft_pending: &mut Vec<Box<Request>>,
                   stats: &CoordStats,
                   req: Box<Request>| {
            match &req.payload {
                Payload::Classify { features } => {
                    if features.len() != cfg.features {
                        stats.failed.inc();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            result: Err(format!(
                                "expected {} features, got {}",
                                cfg.features,
                                features.len()
                            )),
                            latency: clock.now().saturating_duration_since(req.submitted),
                        });
                        return;
                    }
                    pending.push(req);
                }
                Payload::Dft { re, im } => {
                    if re.len() != cfg.dft_n || im.len() != cfg.dft_n {
                        stats.failed.inc();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            result: Err(format!(
                                "expected {n}+{n} re/im samples, got {}+{}",
                                re.len(),
                                im.len(),
                                n = cfg.dft_n
                            )),
                            latency: clock.now().saturating_duration_since(req.submitted),
                        });
                        return;
                    }
                    dft_pending.push(req);
                }
                Payload::Gemm { model, x, y } => {
                    let result = engine.run(model, &[x, y]).map_err(|e| format!("{model}: {e}"));
                    let latency = clock.now().saturating_duration_since(req.submitted);
                    match &result {
                        Ok(_) => {
                            stats.completed.inc();
                            stats.latency.record(latency);
                            stats.latency_direct.record(latency);
                        }
                        Err(_) => {
                            stats.failed.inc();
                        }
                    }
                    let _ = req.reply.send(Response { id: req.id, result, latency });
                }
                Payload::Conv { filters, image } => {
                    let result = engine
                        .run("conv2d_k3", &[filters, image])
                        .map_err(|e| format!("conv2d_k3: {e}"));
                    let latency = clock.now().saturating_duration_since(req.submitted);
                    match &result {
                        Ok(_) => {
                            stats.completed.inc();
                            stats.latency.record(latency);
                            stats.latency_direct.record(latency);
                        }
                        Err(_) => {
                            stats.failed.inc();
                        }
                    }
                    let _ = req.reply.send(Response { id: req.id, result, latency });
                }
            }
        };

    'outer: loop {
        // continuous drain: pull everything already queued into the
        // two family windows (up to each largest bucket) before
        // deciding what to run
        while pending.len() < max_bucket && dft_pending.len() < dft_max {
            match rx.try_recv() {
                Some(Msg::Req(req)) => {
                    process(&mut engine, &mut pending, &mut dft_pending, &stats, req)
                }
                Some(Msg::Shutdown) => {
                    flush(&mut engine, &mut pending, &stats, FlushWhy::Shutdown);
                    flush_dft(&mut engine, &mut dft_pending, &stats, FlushWhy::Shutdown);
                    break 'outer;
                }
                None => break,
            }
        }
        if pending.len() >= max_bucket {
            flush(&mut engine, &mut pending, &stats, FlushWhy::Full);
            continue;
        }
        if dft_pending.len() >= dft_max {
            flush_dft(&mut engine, &mut dft_pending, &stats, FlushWhy::Full);
            continue;
        }
        // deadline of the oldest pending request across both windows
        let oldest = match (pending.first(), dft_pending.first()) {
            (Some(a), Some(b)) => Some(a.submitted.min(b.submitted)),
            (Some(a), None) => Some(a.submitted),
            (None, Some(b)) => Some(b.submitted),
            (None, None) => None,
        };
        let wait = match oldest {
            Some(t0) => {
                let age = clock.now().saturating_duration_since(t0);
                match cfg.max_delay.checked_sub(age) {
                    Some(rem) if rem > Duration::ZERO => rem,
                    _ => {
                        // flush every window whose own head has expired
                        // (at least one has — `t0` is the older head)
                        let now = clock.now();
                        let expired = |w: &[Box<Request>]| {
                            w.first().is_some_and(|r| {
                                now.saturating_duration_since(r.submitted) >= cfg.max_delay
                            })
                        };
                        if expired(&pending) {
                            flush(&mut engine, &mut pending, &stats, FlushWhy::Deadline);
                        }
                        if expired(&dft_pending) {
                            flush_dft(&mut engine, &mut dft_pending, &stats, FlushWhy::Deadline);
                        }
                        continue;
                    }
                }
            }
            None => Duration::from_millis(50),
        };
        match rx.recv_timeout(wait) {
            Some(Msg::Shutdown) => {
                flush(&mut engine, &mut pending, &stats, FlushWhy::Shutdown);
                flush_dft(&mut engine, &mut dft_pending, &stats, FlushWhy::Shutdown);
                break;
            }
            Some(Msg::Req(req)) => {
                process(&mut engine, &mut pending, &mut dft_pending, &stats, req)
            }
            // timeout: loop back and re-read the clock — the deadline
            // check above decides (a manual clock may not have advanced)
            None => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};
    use std::sync::Mutex;

    /// Mock engine: records calls; MLP output row r = features[0] of row
    /// r repeated over classes (batch size parsed from the model name,
    /// like the real bucket artifacts); gemm returns x unchanged.
    struct MockEngine {
        calls: Arc<Mutex<Vec<(String, usize)>>>,
        fail_on: Option<&'static str>,
        cfg: CoordinatorConfig,
    }

    impl MockEngine {
        fn batch_of(&self, model: &str) -> usize {
            model
                .strip_prefix("mlp_b")
                .or_else(|| model.strip_prefix("dft_b"))
                .and_then(|b| b.parse().ok())
                .unwrap_or_else(|| self.cfg.max_bucket())
        }
    }

    impl InferenceEngine for MockEngine {
        fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            self.calls.lock().unwrap().push((model.to_string(), inputs.len()));
            if self.fail_on == Some("*") || self.fail_on == Some(model) {
                crate::bail!("mock failure");
            }
            if model.starts_with("mlp") {
                let x = inputs[0];
                let (b, f, c) = (self.batch_of(model), self.cfg.features, self.cfg.classes);
                let mut out = vec![0f32; b * c];
                for r in 0..b {
                    for j in 0..c {
                        out[r * c + j] = x[r * f] + j as f32;
                    }
                }
                Ok(out)
            } else if model.starts_with("dft_b") {
                // stacked [2b, n] output like the real DFT plans:
                // yr[r][j] = re[r][0] + j, yi[r][j] = im[r][0] - j — each
                // half row identifies its request, so scatter-back
                // mistakes (wrong row, swapped halves) are visible
                let (xr, xi) = (inputs[0], inputs[1]);
                let (b, n) = (self.batch_of(model), self.cfg.dft_n);
                let mut out = vec![0f32; 2 * b * n];
                for r in 0..b {
                    for j in 0..n {
                        out[r * n + j] = xr[r * n] + j as f32;
                        out[(b + r) * n + j] = xi[r * n] - j as f32;
                    }
                }
                Ok(out)
            } else {
                Ok(inputs[0].to_vec())
            }
        }
    }

    fn start_mock(
        cfg: CoordinatorConfig,
        fail_on: Option<&'static str>,
    ) -> (Coordinator, Arc<Mutex<Vec<(String, usize)>>>) {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let calls2 = calls.clone();
        let weights = MlpWeights::deterministic(&cfg);
        let cfg2 = cfg.clone();
        let coord = Coordinator::start(cfg, weights, move |_shard| {
            Ok(MockEngine { calls: calls2.clone(), fail_on, cfg: cfg2.clone() })
        });
        (coord, calls)
    }

    #[test]
    fn full_batch_executes_once() {
        let cfg = CoordinatorConfig {
            buckets: vec![4],
            max_delay: Duration::from_secs(5),
            ..Default::default()
        };
        let (coord, calls) = start_mock(cfg.clone(), None);
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let mut f = vec![0f32; cfg.features];
                f[0] = i as f32 * 10.0;
                coord.submit(Payload::Classify { features: f }).1
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            let row = resp.result.unwrap();
            assert_eq!(row.len(), cfg.classes);
            assert_eq!(row[0], i as f32 * 10.0, "row routed back to its requester");
            assert_eq!(row[5], i as f32 * 10.0 + 5.0);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.batches.get(), 1, "one full batch");
        assert_eq!(stats.completed.get(), 4);
        assert_eq!(calls.lock().unwrap().len(), 1);
        let bs = stats.bucket(4).unwrap();
        assert_eq!(bs.full.get(), 1, "the flush was a window-full flush");
        assert_eq!(bs.rows.get(), 4);
        assert_eq!(bs.occupancy(), 1.0);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let cfg = CoordinatorConfig {
            buckets: vec![8],
            max_delay: Duration::from_millis(10),
            ..Default::default()
        };
        let (coord, _) = start_mock(cfg.clone(), None);
        let (_, rx) = coord.submit(Payload::Classify { features: vec![1.0; cfg.features] });
        let t0 = Instant::now();
        let resp = rx.recv().unwrap();
        assert!(resp.result.is_ok());
        // generous bound: this only asserts the deadline path fires at
        // all, not its precision (see the manual-clock test for exact
        // semantics) — loaded CI runners must not flake here
        let waited = t0.elapsed();
        assert!(waited < Duration::from_secs(5), "deadline flush took {waited:?}");
        let stats = coord.shutdown();
        assert_eq!(stats.mean_batch_occupancy(), 1.0);
        assert_eq!(stats.bucket(8).unwrap().deadline.get(), 1);
    }

    #[test]
    fn manual_clock_drives_deadline_deterministically() {
        // with an injected clock the deadline flush is a pure function
        // of clock reads: no sleeps, no scheduler timing, no flake
        let (clock, time) = Clock::manual();
        let cfg = CoordinatorConfig {
            buckets: vec![8],
            max_delay: Duration::from_secs(60),
            clock,
            ..Default::default()
        };
        let (coord, _) = start_mock(cfg.clone(), None);
        let (_, rx) = coord.submit(Payload::Classify { features: vec![3.0; cfg.features] });
        // the window is nowhere near its deadline in manual time, so the
        // batcher holds the request; advance past the window and wake
        // the engine loop with an unrelated direct-dispatch request
        time.advance(Duration::from_secs(61));
        let (_, grx) = coord.submit(Payload::Gemm {
            model: "gemm_f32".into(),
            x: vec![1.0],
            y: vec![1.0],
        });
        assert!(grx.recv().unwrap().result.is_ok());
        let resp = rx.recv().unwrap();
        assert_eq!(resp.result.unwrap()[0], 3.0);
        // latency is measured on the same clock: ≥ the advance we made
        assert!(resp.latency >= Duration::from_secs(61), "latency {:?}", resp.latency);
        let stats = coord.shutdown();
        assert_eq!(stats.bucket(8).unwrap().deadline.get(), 1);
    }

    #[test]
    fn deadline_and_shutdown_racing_on_a_partial_window_flush_exactly_once() {
        // the PR 6 deflake follow-up, now deterministic on a manual
        // clock: a partially-filled window whose deadline has already
        // expired when shutdown() lands. The drain loop may notice the
        // expired deadline first (Deadline flush, then an empty-window
        // shutdown that flushes nothing) or take the queued Shutdown
        // first (Shutdown flush) — scheduling picks one — but the rows
        // must scatter back exactly once either way, and the BucketStat
        // counters must record exactly one partial flush of 3 rows.
        for round in 0..20 {
            let (clock, time) = Clock::manual();
            let cfg = CoordinatorConfig {
                buckets: vec![8],
                max_delay: Duration::from_secs(60),
                clock,
                ..Default::default()
            };
            let (coord, calls) = start_mock(cfg.clone(), None);
            let rxs: Vec<_> = (0..3)
                .map(|i| {
                    let mut f = vec![0f32; cfg.features];
                    f[0] = i as f32 * 10.0;
                    coord.submit(Payload::Classify { features: f }).1
                })
                .collect();
            // all three submissions happen-before the advance, so the
            // deadline can only expire with the full window visible —
            // no interleaving can split the three rows across flushes
            time.advance(Duration::from_secs(61));
            // wake the engine loop so the Deadline path gets a chance to
            // race the Shutdown message that follows immediately
            let (_, grx) = coord.submit(Payload::Gemm {
                model: "gemm_f32".into(),
                x: vec![1.0],
                y: vec![1.0],
            });
            let stats = coord.shutdown();
            assert!(grx.recv().unwrap().result.is_ok(), "round {round}");
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().expect("row must come back");
                let row = resp.result.expect("row must succeed");
                assert_eq!(row[0], i as f32 * 10.0, "round {round}: row {i} scattered to its requester");
                assert!(resp.latency >= Duration::from_secs(61), "measured on the manual clock");
                assert!(rx.recv().is_err(), "round {round}: row {i} must arrive exactly once");
            }
            let bs = stats.bucket(8).unwrap();
            assert_eq!(
                bs.deadline.get() + bs.shutdown.get(),
                1,
                "round {round}: exactly one partial flush, by either why (deadline={} shutdown={})",
                bs.deadline.get(),
                bs.shutdown.get()
            );
            assert_eq!(bs.full.get(), 0, "round {round}: 3 rows never fill the 8-bucket");
            assert_eq!(bs.rows.get(), 3, "round {round}: all rows in the one flush");
            assert_eq!(stats.batches.get(), 1, "round {round}");
            assert_eq!(stats.completed.get(), 4, "round {round}: 3 classify + 1 gemm");
            assert_eq!(stats.failed.get(), 0, "round {round}");
            // the engine saw exactly one mlp batch (plus the gemm wake)
            let calls = calls.lock().unwrap();
            let mlp_calls = calls.iter().filter(|(m, _)| m.starts_with("mlp")).count();
            assert_eq!(mlp_calls, 1, "round {round}: {calls:?}");
        }
    }

    #[test]
    fn shutdown_flush_uses_smallest_sufficient_bucket() {
        // r pending rows must execute in the smallest ladder bucket ≥ r
        for (r, expect) in [(1usize, 1usize), (2, 8), (8, 8), (9, 32), (32, 32)] {
            let cfg = CoordinatorConfig {
                buckets: vec![1, 8, 32],
                max_delay: Duration::from_secs(60),
                ..Default::default()
            };
            let (coord, calls) = start_mock(cfg.clone(), None);
            let rxs: Vec<_> = (0..r)
                .map(|_| {
                    coord.submit(Payload::Classify { features: vec![1.0; cfg.features] }).1
                })
                .collect();
            let stats = coord.shutdown();
            for rx in rxs {
                assert!(rx.recv().unwrap().result.is_ok());
            }
            let calls = calls.lock().unwrap();
            assert_eq!(calls.len(), 1, "rows={r}: exactly one batch");
            assert_eq!(
                calls[0].0,
                format!("mlp_b{expect}"),
                "rows={r} must land in bucket {expect}"
            );
            let bs = stats.bucket(expect).unwrap();
            assert_eq!(bs.shutdown.get(), 1, "rows={r}: shutdown flush");
            assert_eq!(bs.rows.get(), r as u64);
        }
    }

    #[test]
    fn bucket_selection_invariants_under_mixed_occupancy() {
        // whatever the interleaving, every executed batch of b rows must
        // have used the smallest bucket ≥ b: per bucket, rows ≤
        // flushes·bucket and rows > flushes·(next smaller bucket)
        check("smallest sufficient bucket", 5, |rng: &mut Rng| {
            let ladder = [1usize, 4, 16];
            let cfg = CoordinatorConfig {
                buckets: ladder.to_vec(),
                max_delay: Duration::from_millis(1),
                ..Default::default()
            };
            let n = rng.range(1, 60);
            let (coord, _) = start_mock(cfg.clone(), None);
            let mut rxs = Vec::new();
            for i in 0..n {
                let mut f = vec![0f32; cfg.features];
                f[0] = i as f32;
                rxs.push((i, coord.submit(Payload::Classify { features: f }).1));
            }
            for (i, rx) in rxs {
                let row = rx.recv().unwrap().result.unwrap();
                assert_eq!(row[0] as usize, i, "response routed to wrong requester");
            }
            let stats = coord.shutdown();
            let mut total_rows = 0u64;
            for (bi, &b) in ladder.iter().enumerate() {
                let bs = stats.bucket(b).unwrap();
                let (flushes, rows) = (bs.flushes(), bs.rows.get());
                total_rows += rows;
                assert!(rows <= flushes * b as u64, "bucket {b}: rows {rows} > cap");
                let prev = if bi == 0 { 0 } else { ladder[bi - 1] as u64 };
                assert!(
                    rows >= flushes * (prev + 1),
                    "bucket {b}: {flushes} flushes carried only {rows} rows — \
                     a smaller bucket would have sufficed"
                );
            }
            assert_eq!(total_rows, n as u64, "every request accounted to exactly one bucket");
            assert_eq!(stats.completed.get(), n as u64);
        });
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        check("router loses nothing", 5, |rng: &mut Rng| {
            let cfg = CoordinatorConfig {
                buckets: vec![4],
                max_delay: Duration::from_millis(1),
                ..Default::default()
            };
            let n = rng.range(1, 40);
            let (coord, _) = start_mock(cfg.clone(), None);
            let mut rxs = Vec::new();
            for i in 0..n {
                let mut f = vec![0f32; cfg.features];
                f[0] = i as f32;
                rxs.push((i, coord.submit(Payload::Classify { features: f }).1));
            }
            let mut seen = std::collections::HashSet::new();
            for (i, rx) in rxs {
                let resp = rx.recv().unwrap();
                let row = resp.result.unwrap();
                assert_eq!(row[0] as usize, i, "response routed to wrong requester");
                assert!(seen.insert(i), "duplicate response for {i}");
            }
            let stats = coord.shutdown();
            assert_eq!(stats.completed.get(), n as u64);
            assert_eq!(stats.failed.get(), 0);
        });
    }

    #[test]
    fn scatter_back_row_exact_under_interleaved_families() {
        // classify rows and direct-dispatch requests interleaved at
        // random: every response must carry exactly its own request's
        // data, whatever bucket its window executed in
        check("scatter-back row-exact", 5, |rng: &mut Rng| {
            let cfg = CoordinatorConfig {
                buckets: vec![1, 4, 8],
                max_delay: Duration::from_millis(1),
                ..Default::default()
            };
            let n = rng.range(5, 50);
            let (coord, _) = start_mock(cfg.clone(), None);
            let mut rxs = Vec::new();
            for i in 0..n {
                if rng.range(0, 3) == 0 {
                    let x = vec![i as f32 + 0.25];
                    rxs.push((i, true, coord.submit(Payload::Gemm {
                        model: "gemm_f32".into(),
                        x,
                        y: vec![0.0],
                    }).1));
                } else {
                    let mut f = vec![0f32; cfg.features];
                    f[0] = i as f32;
                    rxs.push((i, false, coord.submit(Payload::Classify { features: f }).1));
                }
            }
            for (i, is_gemm, rx) in rxs {
                let row = rx.recv().unwrap().result.unwrap();
                if is_gemm {
                    assert_eq!(row, vec![i as f32 + 0.25], "gemm echo for {i}");
                } else {
                    assert_eq!(row[0] as usize, i, "classify row for {i}");
                }
            }
            let stats = coord.shutdown();
            assert_eq!(stats.completed.get(), n as u64);
            assert_eq!(stats.failed.get(), 0);
        });
    }

    /// Engine whose gemm calls block until the test releases a token —
    /// pins requests in flight so policy caps are observable without
    /// sleeps.
    struct GatedEngine {
        gate: rt::Receiver<()>,
        inner: MockEngine,
    }

    impl InferenceEngine for GatedEngine {
        fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            if model == "gemm_f32" {
                let _ = self.gate.recv();
            }
            self.inner.run(model, inputs)
        }
    }

    fn start_gated(cfg: CoordinatorConfig) -> (Coordinator, rt::Sender<()>) {
        let (gtx, grx) = rt::bounded::<()>(64);
        let weights = MlpWeights::deterministic(&cfg);
        let cfg2 = cfg.clone();
        let grx = Mutex::new(Some(grx));
        let coord = Coordinator::start(cfg, weights, move |_shard| {
            Ok(GatedEngine {
                gate: grx.lock().unwrap().take().expect("single shard"),
                inner: MockEngine {
                    calls: Arc::new(Mutex::new(Vec::new())),
                    fail_on: None,
                    cfg: cfg2.clone(),
                },
            })
        });
        (coord, gtx)
    }

    #[test]
    fn inflight_cap_throttles_one_family_only() {
        let cfg = CoordinatorConfig {
            buckets: vec![4],
            max_delay: Duration::from_millis(1),
            policies: vec![ModelPolicy::capped("gemm_f32", 2)],
            ..Default::default()
        };
        let (coord, gate) = start_gated(cfg.clone());
        let gemm = |v: f32| Payload::Gemm { model: "gemm_f32".into(), x: vec![v], y: vec![0.0] };
        // two admitted (the cap), pinned in flight by the gate
        let rx1 = coord.try_submit(gemm(1.0)).expect("first under cap").1;
        let rx2 = coord.try_submit(gemm(2.0)).expect("second under cap").1;
        // third gemm is throttled by the family cap...
        assert!(coord.try_submit(gemm(3.0)).is_err());
        assert_eq!(coord.stats.throttled.get(), 1);
        assert_eq!(coord.stats.rejected.get(), 0, "policy throttle is not queue rejection");
        // ...while the classify family is unaffected
        let rxc = coord
            .try_submit(Payload::Classify { features: vec![5.0; cfg.features] })
            .expect("uncapped family admitted")
            .1;
        // blocking submit bypasses enforcement (still counted in flight)
        let rx4 = coord.submit(gemm(4.0)).1;
        for _ in 0..3 {
            gate.send(()).unwrap();
        }
        assert_eq!(rx1.recv().unwrap().result.unwrap(), vec![1.0]);
        assert_eq!(rx2.recv().unwrap().result.unwrap(), vec![2.0]);
        assert_eq!(rx4.recv().unwrap().result.unwrap(), vec![4.0]);
        assert_eq!(rxc.recv().unwrap().result.unwrap()[0], 5.0);
        // all replies delivered -> tokens released; the family admits again
        let rx5 = coord.try_submit(gemm(6.0)).expect("cap released after replies").1;
        gate.send(()).unwrap();
        assert_eq!(rx5.recv().unwrap().result.unwrap(), vec![6.0]);
        coord.shutdown();
    }

    #[test]
    fn low_priority_family_sheds_on_half_full_queue() {
        let cfg = CoordinatorConfig {
            buckets: vec![4],
            max_delay: Duration::from_millis(1),
            queue_cap: 4,
            policies: vec![ModelPolicy::low_priority("gemm_low")],
            ..Default::default()
        };
        let (coord, gate) = start_gated(cfg.clone());
        // pin the engine on a gated gemm, then stack two more behind it:
        // the shard queue is now at least half of queue_cap=4
        let blocker = Payload::Gemm { model: "gemm_f32".into(), x: vec![0.0], y: vec![0.0] };
        let mut rxs = vec![coord.submit(blocker.clone()).1];
        rxs.push(coord.submit(blocker.clone()).1);
        rxs.push(coord.submit(blocker.clone()).1);
        // low-priority family is shed...
        let low = Payload::Gemm { model: "gemm_low".into(), x: vec![9.0], y: vec![0.0] };
        assert!(coord.try_submit(low.clone()).is_err());
        assert_eq!(coord.stats.throttled.get(), 1);
        // ...normal-priority traffic still admitted at the same depth
        let rx_ok = coord.try_submit(blocker.clone()).expect("normal family admitted").1;
        rxs.push(rx_ok);
        for _ in 0..rxs.len() {
            gate.send(()).unwrap();
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        // drained queue: the low-priority family is admitted again
        let rx = coord.try_submit(low).expect("admitted once the queue drains").1;
        assert_eq!(rx.recv().unwrap().result.unwrap(), vec![9.0]);
        coord.shutdown();
    }

    #[test]
    fn gemm_and_conv_route_directly() {
        let cfg = CoordinatorConfig::default();
        let (coord, calls) = start_mock(cfg, None);
        let (_, rx) = coord.submit(Payload::Gemm {
            model: "gemm_f32".into(),
            x: vec![1.0, 2.0],
            y: vec![3.0],
        });
        assert_eq!(rx.recv().unwrap().result.unwrap(), vec![1.0, 2.0]);
        let (_, rx) = coord.submit(Payload::Conv { filters: vec![7.0], image: vec![0.0] });
        assert_eq!(rx.recv().unwrap().result.unwrap(), vec![7.0]);
        coord.shutdown();
        let calls = calls.lock().unwrap();
        assert_eq!(calls[0].0, "gemm_f32");
        assert_eq!(calls[1].0, "conv2d_k3");
    }

    #[test]
    fn engine_failure_fails_whole_batch_gracefully() {
        let cfg = CoordinatorConfig {
            buckets: vec![2],
            max_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let (coord, _) = start_mock(cfg.clone(), Some("*"));
        let rx1 = coord.submit(Payload::Classify { features: vec![0.0; cfg.features] }).1;
        let rx2 = coord.submit(Payload::Classify { features: vec![0.0; cfg.features] }).1;
        assert!(rx1.recv().unwrap().result.is_err());
        assert!(rx2.recv().unwrap().result.is_err());
        let stats = coord.shutdown();
        assert_eq!(stats.failed.get(), 2);
        assert_eq!(stats.completed.get(), 0);
    }

    #[test]
    fn malformed_request_rejected_without_poisoning_batch() {
        let cfg = CoordinatorConfig {
            buckets: vec![2],
            max_delay: Duration::from_millis(5),
            ..Default::default()
        };
        let (coord, _) = start_mock(cfg.clone(), None);
        let bad = coord.submit(Payload::Classify { features: vec![1.0; 3] }).1;
        let good = coord.submit(Payload::Classify { features: vec![1.0; cfg.features] }).1;
        assert!(bad.recv().unwrap().result.is_err());
        assert!(good.recv().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn engine_init_failure_fails_requests() {
        let cfg = CoordinatorConfig::default();
        let weights = MlpWeights::deterministic(&cfg);
        let coord = Coordinator::start::<MockEngine, _>(cfg.clone(), weights, |_shard| {
            crate::bail!("no artifacts")
        });
        let (_, rx) = coord.submit(Payload::Classify { features: vec![0.0; cfg.features] });
        let resp = rx.recv().unwrap();
        assert!(resp.result.unwrap_err().contains("engine init failed"));
        coord.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let cfg = CoordinatorConfig {
            buckets: vec![100],
            max_delay: Duration::from_secs(60),
            ..Default::default()
        };
        let (coord, _) = start_mock(cfg.clone(), None);
        let rx = coord.submit(Payload::Classify { features: vec![2.0; cfg.features] }).1;
        let stats = coord.shutdown();
        assert_eq!(rx.recv().unwrap().result.unwrap()[0], 2.0);
        assert_eq!(stats.completed.get(), 1);
        assert_eq!(stats.bucket(100).unwrap().shutdown.get(), 1);
    }

    /// Mock engine that records which shard served each request, so the
    /// sharded test can assert the work was genuinely split.
    struct ShardTagEngine {
        shard: usize,
        served: Arc<Mutex<std::collections::HashSet<usize>>>,
        inner: MockEngine,
    }

    impl InferenceEngine for ShardTagEngine {
        fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            self.served.lock().unwrap().insert(self.shard);
            self.inner.run(model, inputs)
        }
    }

    #[test]
    fn sharded_coordinator_serves_all_requests() {
        // two shards, round-robin routing: every request answered once,
        // responses routed to the right requester, nothing lost
        let cfg = CoordinatorConfig {
            buckets: vec![4],
            max_delay: Duration::from_millis(1),
            shards: 2,
            routing: ShardRouting::RoundRobin,
            ..Default::default()
        };
        let served = Arc::new(Mutex::new(std::collections::HashSet::new()));
        let served2 = served.clone();
        let cfg2 = cfg.clone();
        let weights = MlpWeights::deterministic(&cfg);
        let coord = Coordinator::start(cfg.clone(), weights, move |shard| {
            Ok(ShardTagEngine {
                shard,
                served: served2.clone(),
                inner: MockEngine {
                    calls: Arc::new(Mutex::new(Vec::new())),
                    fail_on: None,
                    cfg: cfg2.clone(),
                },
            })
        });
        assert_eq!(coord.shards(), 2);
        let n = 37usize;
        let mut rxs = Vec::new();
        for i in 0..n {
            let mut f = vec![0f32; cfg.features];
            f[0] = i as f32;
            rxs.push((i, coord.submit(Payload::Classify { features: f }).1));
        }
        for (i, rx) in rxs {
            let row = rx.recv().unwrap().result.unwrap();
            assert_eq!(row[0] as usize, i, "response routed to wrong requester");
        }
        // direct-dispatch families route through shards too
        let (_, rx) = coord.submit(Payload::Gemm {
            model: "gemm_f32".into(),
            x: vec![1.0],
            y: vec![2.0],
        });
        assert_eq!(rx.recv().unwrap().result.unwrap(), vec![1.0]);
        let stats = coord.shutdown();
        assert_eq!(stats.completed.get(), n as u64 + 1);
        assert_eq!(stats.failed.get(), 0);
        // round-robin really split the work: BOTH engine shards ran
        // requests (37 ids alternate across 2 shards, so each gets ~18)
        assert_eq!(
            served.lock().unwrap().len(),
            2,
            "both shards must serve traffic, not one funnel"
        );
    }

    /// Mock engine recording (model, shard) pairs, for routing asserts.
    struct RouteTagEngine {
        shard: usize,
        served: Arc<Mutex<Vec<(String, usize)>>>,
        inner: MockEngine,
    }

    impl InferenceEngine for RouteTagEngine {
        fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            self.served.lock().unwrap().push((model.to_string(), self.shard));
            self.inner.run(model, inputs)
        }
    }

    #[test]
    fn sticky_routing_pins_each_model_family_to_one_shard() {
        // the default policy hashes the model *family*: across many
        // shard counts and interleavings, every request for a given
        // family must land on the same engine (cache affinity) — and
        // every bucket of the classify ladder counts as ONE family, so
        // the whole ladder's plans stay hot on one shard
        let cfg = CoordinatorConfig {
            buckets: vec![1, 2],
            max_delay: Duration::from_millis(1),
            shards: 3,
            ..Default::default() // routing: ModelSticky is the default
        };
        assert_eq!(cfg.routing, ShardRouting::ModelSticky);
        let served = Arc::new(Mutex::new(Vec::new()));
        let served2 = served.clone();
        let cfg2 = cfg.clone();
        let weights = MlpWeights::deterministic(&cfg);
        let coord = Coordinator::start(cfg.clone(), weights, move |shard| {
            Ok(RouteTagEngine {
                shard,
                served: served2.clone(),
                inner: MockEngine {
                    calls: Arc::new(Mutex::new(Vec::new())),
                    fail_on: None,
                    cfg: cfg2.clone(),
                },
            })
        });
        let mut rxs = Vec::new();
        for i in 0..24 {
            let payload = match i % 3 {
                0 => Payload::Classify { features: vec![1.0; cfg.features] },
                1 => Payload::Gemm { model: "gemm_f32".into(), x: vec![1.0], y: vec![1.0] },
                _ => Payload::Gemm { model: "gemm_bf16".into(), x: vec![1.0], y: vec![1.0] },
            };
            rxs.push(coord.submit(payload).1);
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        coord.shutdown();
        let served = served.lock().unwrap();
        let mut shard_of: std::collections::HashMap<String, usize> =
            std::collections::HashMap::new();
        for (model, shard) in served.iter() {
            // executed bucket models (mlp_b1, mlp_b2, ...) all belong to
            // the classify family, which hashes as cfg.mlp_model()
            let family =
                if model.starts_with("mlp_b") { cfg.mlp_model() } else { model.clone() };
            let expect = (crate::rt::fnv1a(family.as_bytes()) as usize) % 3;
            assert_eq!(*shard, expect, "{model} must land on its family's hash shard");
            if let Some(prev) = shard_of.insert(family.clone(), *shard) {
                assert_eq!(prev, *shard, "{family} bounced between shards");
            }
        }
        assert_eq!(shard_of.len(), 3, "all three model families served: {shard_of:?}");
    }

    #[test]
    fn shard_zero_is_treated_as_one() {
        let cfg = CoordinatorConfig { shards: 0, ..Default::default() };
        let (coord, _) = start_mock(cfg.clone(), None);
        assert_eq!(coord.shards(), 1);
        let (_, rx) = coord.submit(Payload::Classify { features: vec![1.0; cfg.features] });
        assert!(rx.recv().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn ladder_normalization() {
        let cfg = CoordinatorConfig { buckets: vec![32, 1, 8, 8, 0], ..Default::default() };
        assert_eq!(cfg.ladder(), vec![1, 8, 32]);
        assert_eq!(cfg.max_bucket(), 32);
        assert_eq!(cfg.mlp_model(), "mlp_b32");
        assert_eq!(cfg.mlp_model_for(8), "mlp_b8");
        let empty = CoordinatorConfig { buckets: vec![], ..Default::default() };
        assert_eq!(empty.ladder(), vec![32], "empty ladder falls back to the legacy b32");
    }

    #[test]
    fn engine_without_small_buckets_degrades_to_pad_to_max() {
        // an engine that only owns the largest bucket's plan (the
        // legacy load_all fixture set) must still serve a 1-row window
        // — padded to the max bucket, as before this PR
        struct OnlyMax(MockEngine);
        impl InferenceEngine for OnlyMax {
            fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
                assert_eq!(model, "mlp_b32", "small buckets are not loaded");
                self.0.run(model, inputs)
            }
            fn has_model(&self, model: &str) -> bool {
                model == "mlp_b32"
            }
        }
        let cfg = CoordinatorConfig {
            buckets: vec![1, 8, 32],
            max_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let cfg2 = cfg.clone();
        let weights = MlpWeights::deterministic(&cfg);
        let coord = Coordinator::start(cfg.clone(), weights, move |_shard| {
            Ok(OnlyMax(MockEngine {
                calls: Arc::new(Mutex::new(Vec::new())),
                fail_on: None,
                cfg: cfg2.clone(),
            }))
        });
        let (_, rx) = coord.submit(Payload::Classify { features: vec![4.0; cfg.features] });
        assert_eq!(rx.recv().unwrap().result.unwrap()[0], 4.0);
        let stats = coord.shutdown();
        assert_eq!(stats.bucket(32).unwrap().rows.get(), 1);
        assert_eq!(stats.bucket(1).unwrap().flushes(), 0);
        assert_eq!(stats.bucket(8).unwrap().flushes(), 0);
    }

    #[test]
    fn dft_requests_batch_and_scatter_back_both_halves() {
        // a full window of DFT requests executes as ONE batched call,
        // and each response carries exactly its own request's yr half
        // followed by its yi half
        let cfg = CoordinatorConfig {
            buckets: vec![4],
            max_delay: Duration::from_secs(5),
            ..Default::default()
        };
        let n = cfg.dft_n;
        let (coord, calls) = start_mock(cfg.clone(), None);
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let mut re = vec![0f32; n];
                let mut im = vec![0f32; n];
                re[0] = i as f32 * 10.0;
                im[0] = i as f32 * 10.0 + 1.0;
                coord.submit(Payload::Dft { re, im }).1
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = rx.recv().unwrap().result.unwrap();
            assert_eq!(row.len(), 2 * n, "yr bins then yi bins");
            assert_eq!(row[0], i as f32 * 10.0, "yr half routed to its requester");
            assert_eq!(row[3], i as f32 * 10.0 + 3.0);
            assert_eq!(row[n], i as f32 * 10.0 + 1.0, "yi half routed to its requester");
            assert_eq!(row[n + 3], i as f32 * 10.0 + 1.0 - 3.0);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed.get(), 4);
        let bs = stats.dft_bucket(4).unwrap();
        assert_eq!(bs.full.get(), 1, "one window-full DFT flush");
        assert_eq!(bs.rows.get(), 4);
        assert_eq!(bs.occupancy(), 1.0);
        // classify buckets untouched — the families batch independently
        assert_eq!(stats.bucket(4).unwrap().flushes(), 0);
        let calls = calls.lock().unwrap();
        assert_eq!(calls.len(), 1);
        assert_eq!(calls[0], ("dft_b4".to_string(), 2), "one call, two input planes");
    }

    #[test]
    fn two_family_traffic_batches_independently_and_stays_row_exact() {
        // classify and DFT requests interleaved at random: every
        // response must carry exactly its own request's data, every
        // flush must be single-family, and both ladders' stats must
        // account for all rows
        check("two-family scatter-back", 5, |rng: &mut Rng| {
            let cfg = CoordinatorConfig {
                buckets: vec![1, 4, 8],
                max_delay: Duration::from_millis(1),
                ..Default::default()
            };
            let n = cfg.dft_n;
            let total = rng.range(5, 50);
            let (coord, calls) = start_mock(cfg.clone(), None);
            let mut rxs = Vec::new();
            let mut dfts = 0u64;
            let mut classifies = 0u64;
            for i in 0..total {
                if rng.range(0, 2) == 0 {
                    let mut re = vec![0f32; n];
                    let mut im = vec![0f32; n];
                    re[0] = i as f32;
                    im[0] = i as f32 + 0.5;
                    dfts += 1;
                    rxs.push((i, true, coord.submit(Payload::Dft { re, im }).1));
                } else {
                    let mut f = vec![0f32; cfg.features];
                    f[0] = i as f32;
                    classifies += 1;
                    rxs.push((i, false, coord.submit(Payload::Classify { features: f }).1));
                }
            }
            for (i, is_dft, rx) in rxs {
                let row = rx.recv().unwrap().result.unwrap();
                if is_dft {
                    assert_eq!(row.len(), 2 * n, "dft row {i}");
                    assert_eq!(row[0] as usize, i, "dft yr row for {i}");
                    assert_eq!(row[n], i as f32 + 0.5, "dft yi row for {i}");
                } else {
                    assert_eq!(row[0] as usize, i, "classify row for {i}");
                }
            }
            let stats = coord.shutdown();
            assert_eq!(stats.completed.get(), total as u64);
            assert_eq!(stats.failed.get(), 0);
            let dft_rows: u64 = stats.dft_buckets.iter().map(|b| b.rows.get()).sum();
            let mlp_rows: u64 = stats.buckets.iter().map(|b| b.rows.get()).sum();
            assert_eq!(dft_rows, dfts, "every DFT row accounted to a dft bucket");
            assert_eq!(mlp_rows, classifies, "every classify row accounted to an mlp bucket");
            // no engine call ever mixed families
            for (model, ins) in calls.lock().unwrap().iter() {
                assert!(
                    model.starts_with("mlp_b") && *ins == 5
                        || model.starts_with("dft_b") && *ins == 2,
                    "unexpected engine call {model} with {ins} inputs"
                );
            }
        });
    }

    #[test]
    fn dft_deadline_flush_on_manual_clock() {
        // a lone DFT request held in its window must flush by deadline
        // on the manual clock, exactly like classify — same windowing
        // machinery, separate window
        let (clock, time) = Clock::manual();
        let cfg = CoordinatorConfig {
            buckets: vec![8],
            max_delay: Duration::from_secs(60),
            clock,
            ..Default::default()
        };
        let n = cfg.dft_n;
        let (coord, _) = start_mock(cfg.clone(), None);
        let mut re = vec![0f32; n];
        re[0] = 7.0;
        let (_, rx) = coord.submit(Payload::Dft { re, im: vec![0f32; n] });
        time.advance(Duration::from_secs(61));
        // wake the engine loop with a direct-dispatch request
        let (_, grx) =
            coord.submit(Payload::Gemm { model: "gemm_f32".into(), x: vec![1.0], y: vec![1.0] });
        assert!(grx.recv().unwrap().result.is_ok());
        let resp = rx.recv().unwrap();
        assert_eq!(resp.result.unwrap()[0], 7.0);
        assert!(resp.latency >= Duration::from_secs(61));
        let stats = coord.shutdown();
        assert_eq!(stats.dft_bucket(8).unwrap().deadline.get(), 1);
    }

    #[test]
    fn malformed_dft_request_rejected_without_poisoning_window() {
        let cfg = CoordinatorConfig {
            buckets: vec![2],
            max_delay: Duration::from_millis(5),
            ..Default::default()
        };
        let n = cfg.dft_n;
        let (coord, _) = start_mock(cfg.clone(), None);
        let bad = coord.submit(Payload::Dft { re: vec![1.0; 3], im: vec![0.0; n] }).1;
        let good = coord.submit(Payload::Dft { re: vec![1.0; n], im: vec![0.0; n] }).1;
        let resp = bad.recv().unwrap();
        assert!(resp.result.unwrap_err().contains("re/im samples"));
        assert!(good.recv().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn dft_family_policy_throttles_with_per_family_counter() {
        // a low-priority DFT family sheds when its shard queue is half
        // full, and the per-family throttle counter records exactly the
        // DFT sheds while other families stay admitted
        let dft_family = CoordinatorConfig::default().dft_model();
        let cfg = CoordinatorConfig {
            buckets: vec![4],
            max_delay: Duration::from_millis(1),
            queue_cap: 4,
            policies: vec![ModelPolicy::low_priority(&dft_family)],
            ..Default::default()
        };
        let n = cfg.dft_n;
        let (coord, gate) = start_gated(cfg.clone());
        // pin the engine on gated gemms until the queue is half full
        let blocker = Payload::Gemm { model: "gemm_f32".into(), x: vec![0.0], y: vec![0.0] };
        let mut rxs = vec![coord.submit(blocker.clone()).1];
        rxs.push(coord.submit(blocker.clone()).1);
        rxs.push(coord.submit(blocker.clone()).1);
        let dft = Payload::Dft { re: vec![1.0; n], im: vec![0.0; n] };
        assert!(coord.try_submit(dft.clone()).is_err(), "low-priority DFT shed under load");
        assert_eq!(coord.stats.throttled.get(), 1);
        assert_eq!(coord.throttled_for(&dft_family), Some(1), "family-sliced counter");
        assert_eq!(coord.throttled_for("gemm_f32"), None, "untracked family has no policy");
        // normal-priority traffic still admitted at the same depth
        rxs.push(coord.try_submit(blocker.clone()).expect("normal family admitted").1);
        for _ in 0..rxs.len() {
            gate.send(()).unwrap();
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        // drained queue: the DFT family is admitted again
        let rx = coord.try_submit(dft).expect("admitted once the queue drains").1;
        assert_eq!(rx.recv().unwrap().result.unwrap()[0], 1.0);
        assert_eq!(coord.throttled_for(&dft_family), Some(1), "no new sheds");
        coord.shutdown();
    }
}
