//! The serving coordinator — the §I "data-in-flight" scenario: "a system
//! processing data-in-flight is likely to be evaluating multiple distinct
//! models at once … Agility and flexibility of switching models, while
//! performing well, are important."
//!
//! Rust owns the event loop (and everything else on the request path —
//! python ran once, at AOT time):
//!
//! * a **router** dispatches each request to its model family (tabular
//!   classification / GEMM / convolution);
//! * a **dynamic batcher** coalesces classification requests up to the
//!   compiled batch size or a latency deadline, pads the tail, executes
//!   one batched MLP inference, and scatters the rows back to callers;
//! * **backpressure** comes from the bounded per-shard submission queues;
//! * the executables run on **`shards` engine threads**
//!   ([`CoordinatorConfig::shards`]), each with its own bounded queue
//!   and its own engine instance; requests route per [`ShardRouting`] —
//!   by default a request's **model name hashes to a sticky shard**, so
//!   a model family's compiled plan and packed-panel buffers stay hot
//!   on one engine (round-robin by id stays available for
//!   single-model-dominated traffic). Backends may be thread-confined —
//!   each engine is constructed *inside* its thread via the factory, so
//!   no `Send` requirement leaks.
//!
//! ## Threading and ownership contract
//!
//! The request lifecycle is: caller thread → [`Coordinator::submit`]
//! (bounded per-shard channel) → **engine thread** (router + batcher) →
//! compiled model → per-request reply channel. Three rules keep this
//! sound:
//!
//! 1. **Engines are thread-confined.** The `engine_factory` runs once on
//!    each shard's engine thread and the resulting [`InferenceEngine`]
//!    never crosses a thread boundary afterwards; only the factory
//!    itself must be `Send + Sync`. Models may therefore use interior
//!    mutability freely (the plan backend's preallocated
//!    [`plan::ExecBuffers`](crate::runtime::plan::ExecBuffers)
//!    lock is uncontended by construction).
//! 2. **Data-parallel workers come from one shared pool.** The blocked
//!    GEMM behind the plan backend ([`crate::blas::block_gemm`]) fans
//!    its column-chunk loop out over the **persistent worker pool** of a
//!    [`Device`](crate::runtime::device::Device); the dispatch drains
//!    *inside* each `dot` (the engine thread participates and blocks
//!    until its chunks finish), so from the coordinator's point of view
//!    `run()` is still a synchronous call and shutdown ordering
//!    (`Msg::Shutdown` → flush → join) is unchanged. Because every shard
//!    draws from the same device pool, adding shards multiplies
//!    throughput without multiplying GEMM worker threads — shards cannot
//!    oversubscribe the core budget.
//! 3. **Responses are owned, requests are moved.** A request's payload
//!    moves into its shard's engine thread; the reply channel is the
//!    only route back. Nothing on the hot path is shared mutable state
//!    except the atomic [`CoordStats`] counters (shared by all shards).

use crate::error::Result;
use crate::metrics::{Counter, Histogram};
use crate::rt;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Abstraction over the model runtime so the coordinator is unit-testable
/// without compiled artifacts.
pub trait InferenceEngine {
    /// Execute `model` on flat f32 inputs, returning the flat output.
    fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>>;
}

impl InferenceEngine for crate::runtime::Runtime {
    fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        self.execute(model, inputs)
    }
}

/// A request payload: one of the model families served.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Tabular features for the batched MLP classifier.
    Classify { features: Vec<f32> },
    /// A 128×128 GEMM tile (`model` = `gemm_f32` or `gemm_bf16`).
    Gemm { model: String, x: Vec<f32>, y: Vec<f32> },
    /// 8 filter banks over a 3-channel image (the SCONV service).
    Conv { filters: Vec<f32>, image: Vec<f32> },
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<Vec<f32>, String>,
    /// Submit → reply latency.
    pub latency: Duration,
}

struct Request {
    id: u64,
    payload: Payload,
    submitted: Instant,
    reply: rt::Sender<Response>,
}

enum Msg {
    Req(Box<Request>),
    Shutdown,
}

/// How requests map to engine shards (the ROADMAP "shard-aware routing
/// / model affinity" policy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardRouting {
    /// **Sticky (the default):** hash the request's *model name* to a
    /// shard, so a model family always lands on the same engine — its
    /// compiled plan, arena, and packed-panel scratch stay hot in that
    /// engine's caches instead of ping-ponging across shards. The hash
    /// (FNV-1a) is deterministic across runs and processes.
    ModelSticky,
    /// Spread requests round-robin by request id — even load regardless
    /// of model mix (the pre-affinity behavior; the right choice when
    /// traffic is dominated by a single model family, where stickiness
    /// would funnel everything through one shard).
    RoundRobin,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Compiled MLP batch size (must match an artifact, e.g. `mlp_b32`).
    pub batch_size: usize,
    /// Maximum time the batcher holds a partial batch.
    pub max_delay: Duration,
    /// Bounded submission queue depth **per shard** (backpressure).
    pub queue_cap: usize,
    /// Number of engine threads (shards). Each shard runs its own engine
    /// behind its own bounded queue; requests are routed per
    /// [`CoordinatorConfig::routing`]. Engines built over
    /// [`Runtime`](crate::runtime::Runtime)s that share a
    /// [`Device`](crate::runtime::device::Device) draw their GEMM
    /// workers from the one shared pool, so shards scale request
    /// concurrency without oversubscribing cores. `0` is treated as `1`.
    pub shards: usize,
    /// Request→shard policy: sticky model-affinity hashing by default,
    /// [`ShardRouting::RoundRobin`] to keep the legacy even spread.
    pub routing: ShardRouting,
    /// MLP feature/class dims (must match `python/compile/model.py`).
    pub features: usize,
    pub classes: usize,
    pub hidden: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batch_size: 32,
            max_delay: Duration::from_millis(2),
            queue_cap: 1024,
            shards: 1,
            routing: ShardRouting::ModelSticky,
            features: 64,
            classes: 32,
            hidden: 128,
        }
    }
}

impl CoordinatorConfig {
    pub fn mlp_model(&self) -> String {
        format!("mlp_b{}", self.batch_size)
    }
}

/// Shared serving statistics.
#[derive(Default)]
pub struct CoordStats {
    pub received: Counter,
    pub completed: Counter,
    pub failed: Counter,
    pub rejected: Counter,
    pub batches: Counter,
    /// Sum of batch occupancies (completed classify requests).
    pub batched_requests: Counter,
    pub latency: Histogram,
}

impl CoordStats {
    /// Mean rows per executed MLP batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batched_requests.get() as f64 / b as f64
        }
    }
}

/// Handle to a running coordinator (one submission queue + engine
/// thread per shard; requests route per [`ShardRouting`] — sticky
/// model-name hashing by default, round-robin by request id on demand).
pub struct Coordinator {
    txs: Vec<rt::Sender<Msg>>,
    engine_threads: Vec<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    routing: ShardRouting,
    /// The batched-MLP model name (what a `Classify` hashes as).
    mlp_model: String,
    pub stats: Arc<CoordStats>,
}

/// The MLP weights the service hosts. Deterministic (same formula as the
/// AOT expected-output fixtures) so end-to-end numerics are checkable.
#[derive(Clone)]
pub struct MlpWeights {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl MlpWeights {
    /// The weights `aot.py` baked expectations for (salts 2..=5).
    pub fn deterministic(cfg: &CoordinatorConfig) -> Self {
        use crate::runtime::det_input;
        MlpWeights {
            w1: det_input(cfg.features * cfg.hidden, 2),
            b1: det_input(cfg.hidden, 3),
            w2: det_input(cfg.hidden * cfg.classes, 4),
            b2: det_input(cfg.classes, 5),
        }
    }
}

impl Coordinator {
    /// Start the coordinator with [`CoordinatorConfig::shards`] engine
    /// threads. `engine_factory` runs once *on each shard's engine
    /// thread* (thread-confined backends never cross threads) and
    /// receives the shard index; it must be `Sync` because all shards
    /// share it. For a single-shard coordinator the factory is called
    /// exactly once, preserving the legacy behavior.
    pub fn start<E, F>(cfg: CoordinatorConfig, weights: MlpWeights, engine_factory: F) -> Self
    where
        E: InferenceEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let routing = cfg.routing;
        let mlp_model = cfg.mlp_model();
        let stats = Arc::new(CoordStats::default());
        let factory = Arc::new(engine_factory);
        let mut txs = Vec::with_capacity(shards);
        let mut engine_threads = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = rt::bounded::<Msg>(cfg.queue_cap);
            let fac = factory.clone();
            let cfg2 = cfg.clone();
            let weights2 = weights.clone();
            let stats2 = stats.clone();
            let handle = std::thread::Builder::new()
                .name(format!("mma-engine-{shard}"))
                .spawn(move || engine_loop(cfg2, weights2, move || (*fac)(shard), rx, stats2))
                .expect("spawn engine thread");
            txs.push(tx);
            engine_threads.push(handle);
        }
        Coordinator {
            txs,
            engine_threads,
            next_id: std::sync::atomic::AtomicU64::new(1),
            routing,
            mlp_model,
            stats,
        }
    }

    /// Number of engine shards.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// The model a payload executes — what the sticky router hashes.
    fn model_of<'a>(&'a self, payload: &'a Payload) -> &'a str {
        match payload {
            Payload::Classify { .. } => &self.mlp_model,
            Payload::Gemm { model, .. } => model,
            Payload::Conv { .. } => "conv2d_k3",
        }
    }

    /// The shard index a request routes to, per the configured policy.
    /// The sticky hash is the crate-wide deterministic FNV-1a
    /// ([`rt::fnv1a`]) — never `DefaultHasher`, whose algorithm is
    /// unspecified — so the shard a model lands on is stable across
    /// runs, processes, and toolchains.
    fn shard_index(&self, id: u64, payload: &Payload) -> usize {
        match self.routing {
            ShardRouting::RoundRobin => (id as usize) % self.txs.len(),
            ShardRouting::ModelSticky => {
                (rt::fnv1a(self.model_of(payload).as_bytes()) as usize) % self.txs.len()
            }
        }
    }

    /// Submit a request; returns a receiver for the response. Fails fast
    /// (`Err(id)`) when the target shard's queue is full — the
    /// backpressure signal.
    pub fn try_submit(&self, payload: Payload) -> Result<(u64, rt::Receiver<Response>), u64> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let shard = self.shard_index(id, &payload);
        let (rtx, rrx) = rt::bounded(1);
        let req = Box::new(Request { id, payload, submitted: Instant::now(), reply: rtx });
        self.stats.received.inc();
        match self.txs[shard].try_send(Msg::Req(req)) {
            Ok(()) => Ok((id, rrx)),
            Err(_) => {
                self.stats.rejected.inc();
                Err(id)
            }
        }
    }

    /// Blocking submit (waits for queue space on the target shard).
    pub fn submit(&self, payload: Payload) -> (u64, rt::Receiver<Response>) {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let shard = self.shard_index(id, &payload);
        let (rtx, rrx) = rt::bounded(1);
        let req = Box::new(Request { id, payload, submitted: Instant::now(), reply: rtx });
        self.stats.received.inc();
        self.txs[shard].send(Msg::Req(req)).ok();
        (id, rrx)
    }

    /// Drain and stop every engine shard.
    pub fn shutdown(mut self) -> Arc<CoordStats> {
        for tx in &self.txs {
            tx.send(Msg::Shutdown).ok();
        }
        for h in self.engine_threads.drain(..) {
            h.join().expect("engine thread panicked");
        }
        self.stats.clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if !self.engine_threads.is_empty() {
            for tx in &self.txs {
                tx.send(Msg::Shutdown).ok();
            }
            for h in self.engine_threads.drain(..) {
                let _ = h.join();
            }
        }
    }
}

fn engine_loop<E, F>(
    cfg: CoordinatorConfig,
    weights: MlpWeights,
    factory: F,
    rx: rt::Receiver<Msg>,
    stats: Arc<CoordStats>,
) where
    E: InferenceEngine,
    F: FnOnce() -> Result<E>,
{
    let mut engine = match factory() {
        Ok(e) => e,
        Err(e) => {
            // fail every request with the construction error
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Req(req) => {
                        stats.failed.inc();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            result: Err(format!("engine init failed: {e}")),
                            latency: req.submitted.elapsed(),
                        });
                    }
                    Msg::Shutdown => break,
                }
            }
            return;
        }
    };
    let mlp_model = cfg.mlp_model();
    let mut pending: Vec<Box<Request>> = Vec::with_capacity(cfg.batch_size);

    let flush = |engine: &mut E, pending: &mut Vec<Box<Request>>, stats: &CoordStats| {
        if pending.is_empty() {
            return;
        }
        let rows = pending.len();
        // gather + pad to the compiled batch size
        let mut xbatch = vec![0f32; cfg.batch_size * cfg.features];
        for (r, req) in pending.iter().enumerate() {
            if let Payload::Classify { features } = &req.payload {
                xbatch[r * cfg.features..(r + 1) * cfg.features].copy_from_slice(features);
            }
        }
        let result = engine.run(
            &mlp_model,
            &[&xbatch, &weights.w1, &weights.b1, &weights.w2, &weights.b2],
        );
        stats.batches.inc();
        stats.batched_requests.add(rows as u64);
        match result {
            Ok(out) => {
                for (r, req) in pending.drain(..).enumerate() {
                    let row = out[r * cfg.classes..(r + 1) * cfg.classes].to_vec();
                    let latency = req.submitted.elapsed();
                    stats.completed.inc();
                    stats.latency.record(latency);
                    let _ = req.reply.send(Response { id: req.id, result: Ok(row), latency });
                }
            }
            Err(e) => {
                for req in pending.drain(..) {
                    stats.failed.inc();
                    let _ = req.reply.send(Response {
                        id: req.id,
                        result: Err(format!("batch failed: {e}")),
                        latency: req.submitted.elapsed(),
                    });
                }
            }
        }
    };

    loop {
        // deadline of the oldest pending classification, if any
        let wait = if let Some(first) = pending.first() {
            cfg.max_delay.saturating_sub(first.submitted.elapsed())
        } else {
            Duration::from_millis(50)
        };
        match rx.recv_timeout(wait) {
            Some(Msg::Shutdown) => {
                flush(&mut engine, &mut pending, &stats);
                break;
            }
            Some(Msg::Req(req)) => match &req.payload {
                Payload::Classify { features } => {
                    if features.len() != cfg.features {
                        stats.failed.inc();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            result: Err(format!(
                                "expected {} features, got {}",
                                cfg.features,
                                features.len()
                            )),
                            latency: req.submitted.elapsed(),
                        });
                        continue;
                    }
                    pending.push(req);
                    if pending.len() >= cfg.batch_size {
                        flush(&mut engine, &mut pending, &stats);
                    }
                }
                Payload::Gemm { model, x, y } => {
                    let result =
                        engine.run(model, &[x, y]).map_err(|e| format!("{model}: {e}"));
                    let latency = req.submitted.elapsed();
                    match &result {
                        Ok(_) => {
                            stats.completed.inc();
                            stats.latency.record(latency);
                        }
                        Err(_) => {
                            stats.failed.inc();
                        }
                    }
                    let _ = req.reply.send(Response { id: req.id, result, latency });
                }
                Payload::Conv { filters, image } => {
                    let result = engine
                        .run("conv2d_k3", &[filters, image])
                        .map_err(|e| format!("conv2d_k3: {e}"));
                    let latency = req.submitted.elapsed();
                    match &result {
                        Ok(_) => {
                            stats.completed.inc();
                            stats.latency.record(latency);
                        }
                        Err(_) => {
                            stats.failed.inc();
                        }
                    }
                    let _ = req.reply.send(Response { id: req.id, result, latency });
                }
            },
            None => {
                // deadline expired (or idle): flush partial batch
                flush(&mut engine, &mut pending, &stats);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    /// Mock engine: records calls; MLP output row r = features[0] of row r
    /// repeated over classes; gemm returns x unchanged; conv errors.
    struct MockEngine {
        calls: Arc<Mutex<Vec<(String, usize)>>>,
        fail_on: Option<&'static str>,
        cfg: CoordinatorConfig,
    }

    impl InferenceEngine for MockEngine {
        fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            self.calls.lock().unwrap().push((model.to_string(), inputs.len()));
            if Some(model) == self.fail_on.map(|s| s) || self.fail_on == Some("*") {
                crate::bail!("mock failure");
            }
            if model.starts_with("mlp") {
                let x = inputs[0];
                let (b, f, c) = (self.cfg.batch_size, self.cfg.features, self.cfg.classes);
                let mut out = vec![0f32; b * c];
                for r in 0..b {
                    for j in 0..c {
                        out[r * c + j] = x[r * f] + j as f32;
                    }
                }
                Ok(out)
            } else {
                Ok(inputs[0].to_vec())
            }
        }
    }

    fn start_mock(
        cfg: CoordinatorConfig,
        fail_on: Option<&'static str>,
    ) -> (Coordinator, Arc<Mutex<Vec<(String, usize)>>>) {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let calls2 = calls.clone();
        let weights = MlpWeights::deterministic(&cfg);
        let cfg2 = cfg.clone();
        let coord = Coordinator::start(cfg, weights, move |_shard| {
            Ok(MockEngine { calls: calls2.clone(), fail_on, cfg: cfg2.clone() })
        });
        (coord, calls)
    }

    #[test]
    fn full_batch_executes_once() {
        let cfg = CoordinatorConfig { batch_size: 4, max_delay: Duration::from_secs(5), ..Default::default() };
        let (coord, calls) = start_mock(cfg.clone(), None);
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let mut f = vec![0f32; cfg.features];
                f[0] = i as f32 * 10.0;
                coord.submit(Payload::Classify { features: f }).1
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            let row = resp.result.unwrap();
            assert_eq!(row.len(), cfg.classes);
            assert_eq!(row[0], i as f32 * 10.0, "row routed back to its requester");
            assert_eq!(row[5], i as f32 * 10.0 + 5.0);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.batches.get(), 1, "one full batch");
        assert_eq!(stats.completed.get(), 4);
        assert_eq!(calls.lock().unwrap().len(), 1);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let cfg = CoordinatorConfig { batch_size: 8, max_delay: Duration::from_millis(10), ..Default::default() };
        let (coord, _) = start_mock(cfg.clone(), None);
        let (_, rx) = coord.submit(Payload::Classify { features: vec![1.0; cfg.features] });
        let t0 = Instant::now();
        let resp = rx.recv().unwrap();
        assert!(resp.result.is_ok());
        let waited = t0.elapsed();
        assert!(waited < Duration::from_millis(500), "deadline flush took {waited:?}");
        let stats = coord.shutdown();
        assert_eq!(stats.mean_batch_occupancy(), 1.0);
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        check("router loses nothing", 5, |rng: &mut Rng| {
            let cfg = CoordinatorConfig {
                batch_size: 4,
                max_delay: Duration::from_millis(1),
                ..Default::default()
            };
            let n = rng.range(1, 40);
            let (coord, _) = start_mock(cfg.clone(), None);
            let mut rxs = Vec::new();
            for i in 0..n {
                let mut f = vec![0f32; cfg.features];
                f[0] = i as f32;
                rxs.push((i, coord.submit(Payload::Classify { features: f }).1));
            }
            let mut seen = std::collections::HashSet::new();
            for (i, rx) in rxs {
                let resp = rx.recv().unwrap();
                let row = resp.result.unwrap();
                assert_eq!(row[0] as usize, i, "response routed to wrong requester");
                assert!(seen.insert(i), "duplicate response for {i}");
            }
            let stats = coord.shutdown();
            assert_eq!(stats.completed.get(), n as u64);
            assert_eq!(stats.failed.get(), 0);
        });
    }

    #[test]
    fn gemm_and_conv_route_directly() {
        let cfg = CoordinatorConfig::default();
        let (coord, calls) = start_mock(cfg, None);
        let (_, rx) = coord.submit(Payload::Gemm {
            model: "gemm_f32".into(),
            x: vec![1.0, 2.0],
            y: vec![3.0],
        });
        assert_eq!(rx.recv().unwrap().result.unwrap(), vec![1.0, 2.0]);
        let (_, rx) = coord.submit(Payload::Conv { filters: vec![7.0], image: vec![0.0] });
        assert_eq!(rx.recv().unwrap().result.unwrap(), vec![7.0]);
        coord.shutdown();
        let calls = calls.lock().unwrap();
        assert_eq!(calls[0].0, "gemm_f32");
        assert_eq!(calls[1].0, "conv2d_k3");
    }

    #[test]
    fn engine_failure_fails_whole_batch_gracefully() {
        let cfg = CoordinatorConfig { batch_size: 2, max_delay: Duration::from_millis(1), ..Default::default() };
        let (coord, _) = start_mock(cfg.clone(), Some("*"));
        let rx1 = coord.submit(Payload::Classify { features: vec![0.0; cfg.features] }).1;
        let rx2 = coord.submit(Payload::Classify { features: vec![0.0; cfg.features] }).1;
        assert!(rx1.recv().unwrap().result.is_err());
        assert!(rx2.recv().unwrap().result.is_err());
        let stats = coord.shutdown();
        assert_eq!(stats.failed.get(), 2);
        assert_eq!(stats.completed.get(), 0);
    }

    #[test]
    fn malformed_request_rejected_without_poisoning_batch() {
        let cfg = CoordinatorConfig { batch_size: 2, max_delay: Duration::from_millis(5), ..Default::default() };
        let (coord, _) = start_mock(cfg.clone(), None);
        let bad = coord.submit(Payload::Classify { features: vec![1.0; 3] }).1;
        let good = coord.submit(Payload::Classify { features: vec![1.0; cfg.features] }).1;
        assert!(bad.recv().unwrap().result.is_err());
        assert!(good.recv().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn engine_init_failure_fails_requests() {
        let cfg = CoordinatorConfig::default();
        let weights = MlpWeights::deterministic(&cfg);
        let coord = Coordinator::start::<MockEngine, _>(cfg.clone(), weights, |_shard| {
            crate::bail!("no artifacts")
        });
        let (_, rx) = coord.submit(Payload::Classify { features: vec![0.0; cfg.features] });
        let resp = rx.recv().unwrap();
        assert!(resp.result.unwrap_err().contains("engine init failed"));
        coord.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let cfg = CoordinatorConfig { batch_size: 100, max_delay: Duration::from_secs(60), ..Default::default() };
        let (coord, _) = start_mock(cfg.clone(), None);
        let rx = coord.submit(Payload::Classify { features: vec![2.0; cfg.features] }).1;
        let stats = coord.shutdown();
        assert_eq!(rx.recv().unwrap().result.unwrap()[0], 2.0);
        assert_eq!(stats.completed.get(), 1);
    }

    /// Mock engine that records which shard served each request, so the
    /// sharded test can assert the work was genuinely split.
    struct ShardTagEngine {
        shard: usize,
        served: Arc<Mutex<std::collections::HashSet<usize>>>,
        inner: MockEngine,
    }

    impl InferenceEngine for ShardTagEngine {
        fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            self.served.lock().unwrap().insert(self.shard);
            self.inner.run(model, inputs)
        }
    }

    #[test]
    fn sharded_coordinator_serves_all_requests() {
        // two shards, round-robin routing: every request answered once,
        // responses routed to the right requester, nothing lost
        let cfg = CoordinatorConfig {
            batch_size: 4,
            max_delay: Duration::from_millis(1),
            shards: 2,
            routing: ShardRouting::RoundRobin,
            ..Default::default()
        };
        let served = Arc::new(Mutex::new(std::collections::HashSet::new()));
        let served2 = served.clone();
        let cfg2 = cfg.clone();
        let weights = MlpWeights::deterministic(&cfg);
        let coord = Coordinator::start(cfg.clone(), weights, move |shard| {
            Ok(ShardTagEngine {
                shard,
                served: served2.clone(),
                inner: MockEngine {
                    calls: Arc::new(Mutex::new(Vec::new())),
                    fail_on: None,
                    cfg: cfg2.clone(),
                },
            })
        });
        assert_eq!(coord.shards(), 2);
        let n = 37usize;
        let mut rxs = Vec::new();
        for i in 0..n {
            let mut f = vec![0f32; cfg.features];
            f[0] = i as f32;
            rxs.push((i, coord.submit(Payload::Classify { features: f }).1));
        }
        for (i, rx) in rxs {
            let row = rx.recv().unwrap().result.unwrap();
            assert_eq!(row[0] as usize, i, "response routed to wrong requester");
        }
        // direct-dispatch families route through shards too
        let (_, rx) = coord.submit(Payload::Gemm {
            model: "gemm_f32".into(),
            x: vec![1.0],
            y: vec![2.0],
        });
        assert_eq!(rx.recv().unwrap().result.unwrap(), vec![1.0]);
        let stats = coord.shutdown();
        assert_eq!(stats.completed.get(), n as u64 + 1);
        assert_eq!(stats.failed.get(), 0);
        // round-robin really split the work: BOTH engine shards ran
        // requests (37 ids alternate across 2 shards, so each gets ~18)
        assert_eq!(
            served.lock().unwrap().len(),
            2,
            "both shards must serve traffic, not one funnel"
        );
    }

    /// Mock engine recording (model, shard) pairs, for routing asserts.
    struct RouteTagEngine {
        shard: usize,
        served: Arc<Mutex<Vec<(String, usize)>>>,
        inner: MockEngine,
    }

    impl InferenceEngine for RouteTagEngine {
        fn run(&mut self, model: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            self.served.lock().unwrap().push((model.to_string(), self.shard));
            self.inner.run(model, inputs)
        }
    }

    #[test]
    fn sticky_routing_pins_each_model_family_to_one_shard() {
        // the default policy hashes the model name: across many shard
        // counts and interleavings, every request for a given model must
        // land on the same engine (cache affinity), and the assignment
        // must be the deterministic FNV one
        let cfg = CoordinatorConfig {
            batch_size: 2,
            max_delay: Duration::from_millis(1),
            shards: 3,
            ..Default::default() // routing: ModelSticky is the default
        };
        assert_eq!(cfg.routing, ShardRouting::ModelSticky);
        let served = Arc::new(Mutex::new(Vec::new()));
        let served2 = served.clone();
        let cfg2 = cfg.clone();
        let weights = MlpWeights::deterministic(&cfg);
        let coord = Coordinator::start(cfg.clone(), weights, move |shard| {
            Ok(RouteTagEngine {
                shard,
                served: served2.clone(),
                inner: MockEngine {
                    calls: Arc::new(Mutex::new(Vec::new())),
                    fail_on: None,
                    cfg: cfg2.clone(),
                },
            })
        });
        let mut rxs = Vec::new();
        for i in 0..24 {
            let payload = match i % 3 {
                0 => Payload::Classify { features: vec![1.0; cfg.features] },
                1 => Payload::Gemm { model: "gemm_f32".into(), x: vec![1.0], y: vec![1.0] },
                _ => Payload::Gemm { model: "gemm_bf16".into(), x: vec![1.0], y: vec![1.0] },
            };
            rxs.push(coord.submit(payload).1);
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        coord.shutdown();
        let served = served.lock().unwrap();
        let mut shard_of: std::collections::HashMap<String, usize> =
            std::collections::HashMap::new();
        for (model, shard) in served.iter() {
            let expect = (crate::rt::fnv1a(model.as_bytes()) as usize) % 3;
            assert_eq!(*shard, expect, "{model} must land on its hash shard");
            if let Some(prev) = shard_of.insert(model.clone(), *shard) {
                assert_eq!(prev, *shard, "{model} bounced between shards");
            }
        }
        assert_eq!(shard_of.len(), 3, "all three model families served: {shard_of:?}");
    }

    #[test]
    fn shard_zero_is_treated_as_one() {
        let cfg = CoordinatorConfig { shards: 0, ..Default::default() };
        let (coord, _) = start_mock(cfg.clone(), None);
        assert_eq!(coord.shards(), 1);
        let (_, rx) = coord.submit(Payload::Classify { features: vec![1.0; cfg.features] });
        assert!(rx.recv().unwrap().result.is_ok());
        coord.shutdown();
    }
}
