//! `power-mma` — command-line front end to the reproduction.
//!
//! Subcommands map to the paper's experiments and tools:
//!
//! * `fig10` / `fig11` / `fig12` — regenerate the evaluation figures;
//! * `hpl` — functional HPL (with `--backend sim-mma` every trailing MAC
//!   executes as simulated MMA instructions);
//! * `simulate` — time a kernel on a machine configuration;
//! * `asm` / `disasm` — the Power ISA MMA assembler/disassembler;
//! * `serve` — start the analytics coordinator on the AOT artifacts
//!   (materializing the embedded set when the directory is empty) and run
//!   a self-test load on the native HLO-interpreter backend;
//! * `gen-artifacts` — write the embedded AOT artifact set to disk.

use power_mma::benchkit::f2;
use power_mma::blas::gemm::{RefGemm, SimMmaGemm};
use power_mma::cli::Command;
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::hpl::{hpl_cycles, hpl_run, CycleCost, Setup};
use power_mma::isa::asm;
use power_mma::isa::encode;
use power_mma::kernels::dgemm::dgemm_8xnx8_program;
use power_mma::kernels::vsx::vsx_dgemm_8x4_program;
use power_mma::metrics::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("fig10") => cmd_fig10(&args[1..]),
        Some("fig11") => cmd_fig11(&args[1..]),
        Some("fig12") => cmd_fig12(&args[1..]),
        Some("hpl") => cmd_hpl(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("asm") => cmd_asm(&args[1..]),
        Some("disasm") => cmd_disasm(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("gen-artifacts") => cmd_gen_artifacts(&args[1..]),
        _ => {
            eprintln!(
                "power-mma — reproduction of 'A matrix math facility for Power ISA processors'\n\n\
                 usage: power-mma <command> [options]\n\n\
                 commands:\n\
                 \x20 fig10     HPL flops/cycle vs problem size (paper Figure 10)\n\
                 \x20 fig11     DGEMM flops/cycle vs N (paper Figure 11)\n\
                 \x20 fig12     average power of 128x128 DGEMM (paper Figure 12)\n\
                 \x20 hpl       functional HPL run with residual check\n\
                 \x20 simulate  time a kernel on a machine model\n\
                 \x20 asm       assemble MMA assembly to bytes\n\
                 \x20 disasm    disassemble bytes to MMA assembly\n\
                 \x20 serve     serve the AOT models and run a self-test load\n\
                 \x20 gen-artifacts  write the embedded AOT artifact set to disk\n\n\
                 run `power-mma <command> --help` for options"
            );
            2
        }
    };
    std::process::exit(code);
}

fn parse_or_exit(cmd: Command, args: &[String]) -> power_mma::cli::Matches {
    match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn cmd_fig10(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma fig10", "HPL flops/cycle vs N (Figure 10)")
        .opt("sizes", Some("512,1024,2048,4096,8192"), "problem sizes to sweep")
        .opt("nb", Some("128"), "LU panel width");
    let m = parse_or_exit(cmd, args);
    let sizes = m.get_usize_list("sizes").unwrap();
    let nb = m.get_usize("nb").unwrap();
    let mut table = Table::new(&["N", "POWER9", "POWER10-VSX", "POWER10-MMA", "MMA/P9"]);
    let mut costs: Vec<CycleCost> = Setup::ALL.iter().map(|&s| CycleCost::new(s)).collect();
    for &n in &sizes {
        let mut row = vec![n.to_string()];
        let mut vals = Vec::new();
        for (i, &setup) in Setup::ALL.iter().enumerate() {
            let t = hpl_cycles(setup, n, nb, &mut costs[i]);
            vals.push(t.flops_per_cycle());
            row.push(f2(t.flops_per_cycle()));
        }
        row.push(f2(vals[2] / vals[0]));
        table.row(&row);
    }
    println!("HPL performance (flops/cycle), paper Figure 10:\n{}", table.render());
    0
}

fn cmd_fig11(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma fig11", "DGEMM Nx128 * 128xN flops/cycle (Figure 11)")
        .opt("sizes", Some("128,256,512,1024,2048,4096"), "N values");
    let m = parse_or_exit(cmd, args);
    let sizes = m.get_usize_list("sizes").unwrap();
    let mut table =
        Table::new(&["N", "POWER9", "POWER10-VSX", "POWER10-MMA", "MMA/VSX", "MMA/P9"]);
    let mut costs: Vec<CycleCost> = Setup::ALL.iter().map(|&s| CycleCost::new(s)).collect();
    for &n in &sizes {
        let mut vals = Vec::new();
        for (i, _) in Setup::ALL.iter().enumerate() {
            let cycles = costs[i].dgemm_cycles(n, n, 128);
            let flops = 2.0 * (n * n * 128) as f64;
            vals.push(flops / cycles as f64);
        }
        table.row(&[
            n.to_string(),
            f2(vals[0]),
            f2(vals[1]),
            f2(vals[2]),
            f2(vals[2] / vals[1]),
            f2(vals[2] / vals[0]),
        ]);
    }
    println!("DGEMM performance (flops/cycle), paper Figure 11:\n{}", table.render());
    0
}

fn cmd_fig12(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma fig12", "average power of 128x128 DGEMM (Figure 12)")
        .flag("gate-mme", "power-gate the MME during VSX runs");
    let m = parse_or_exit(cmd, args);
    let gate = m.flag("gate-mme");
    let mut table =
        Table::new(&["config", "CORE w/o MME", "MME", "TOTAL", "flops/cycle", "power/flop"]);
    for setup in Setup::ALL {
        let mut cost = CycleCost::new(setup);
        if gate {
            cost.sim_mut().set_mme_gated(true);
        }
        let r = cost.kernel_report(128);
        let e = &r.energy;
        table.row(&[
            setup.label().to_string(),
            f2(e.core_power),
            f2(e.mme_power),
            f2(e.total_power),
            f2(r.flops_per_cycle()),
            format!("{:.3}", e.total_power / r.flops_per_cycle()),
        ]);
    }
    println!(
        "Average power draw of 128x128 DGEMM (arbitrary units), paper Figure 12{}:\n{}",
        if gate { " (MME power-gated)" } else { "" },
        table.render()
    );
    0
}

fn cmd_hpl(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma hpl", "functional HPL with residual check")
        .opt("n", Some("256"), "problem size")
        .opt("nb", Some("64"), "panel width")
        .opt("backend", Some("reference"), "trailing-update backend: reference | sim-mma")
        .opt("seed", Some("42"), "matrix seed");
    let m = parse_or_exit(cmd, args);
    let n = m.get_usize("n").unwrap();
    let nb = m.get_usize("nb").unwrap();
    let seed = m.get_u64("seed").unwrap();
    let r = match m.get("backend") {
        "sim-mma" => {
            let mut b = SimMmaGemm::default();
            let r = hpl_run(n, nb, seed, &mut b).unwrap();
            println!(
                "trailing updates executed as MMA instruction streams: {} instructions, {} gers",
                b.stats.instructions, b.stats.mma_instructions
            );
            r
        }
        _ => hpl_run(n, nb, seed, &mut RefGemm).unwrap(),
    };
    println!(
        "HPL N={n} NB={nb}: residual {:.3e} -> {}",
        r.residual,
        if r.passed() { "PASSED" } else { "FAILED" }
    );
    println!(
        "nominal {:.3} Gflop; gemm fraction {:.1}%",
        r.nominal_flops() / 1e9,
        100.0 * r.profile.gemm_flops as f64 / r.profile.total_flops() as f64
    );
    if r.passed() {
        0
    } else {
        1
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma simulate", "time a kernel on a machine model")
        .opt("machine", Some("power10"), "power9 | power10")
        .opt("k", Some("128"), "inner dimension of the kernel")
        .positional("kernel", "dgemm-mma | dgemm-vsx");
    let m = parse_or_exit(cmd, args);
    let k = m.get_usize("k").unwrap();
    let cfg = match m.get("machine") {
        "power9" => MachineConfig::power9(),
        _ => MachineConfig::power10(),
    };
    let prog = match m.positional(0) {
        "dgemm-mma" => dgemm_8xnx8_program(k),
        "dgemm-vsx" => vsx_dgemm_8x4_program(k),
        other => {
            eprintln!("unknown kernel {other}");
            return 2;
        }
    };
    let mut sim = CoreSim::new(cfg);
    let r = sim.run(&prog, 1 << 26);
    println!(
        "{} on {}: {} insts, {} cycles, {:.2} flops/cycle (ipc {:.2})",
        m.positional(0),
        r.name,
        r.instructions,
        r.cycles,
        r.flops_per_cycle(),
        r.ipc()
    );
    println!(
        "units: vsu={} mma={} lsu={} fx={} | cache: l1={} l2={} miss={}",
        r.units.vsu_ops, r.units.mma_ops, r.units.lsu_ops, r.units.fx_ops, r.l1_hits, r.l2_hits, r.mem_misses
    );
    0
}

fn cmd_asm(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma asm", "assemble MMA assembly (stdin) to hex")
        .flag("bytes", "print raw bytes instead of words");
    let m = parse_or_exit(cmd, args);
    let mut src = String::new();
    use std::io::Read;
    std::io::stdin().read_to_string(&mut src).expect("read stdin");
    match asm::assemble(&src) {
        Ok(prog) => {
            let bytes = encode::encode_program(&prog).expect("encode");
            if m.flag("bytes") {
                for b in &bytes {
                    print!("{b:02x} ");
                }
                println!();
            } else {
                for w in bytes.chunks_exact(4) {
                    println!("{:08x}", u32::from_le_bytes(w.try_into().unwrap()));
                }
            }
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_disasm(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma disasm", "disassemble hex words (stdin, one per line)");
    let _m = parse_or_exit(cmd, args);
    let mut src = String::new();
    use std::io::Read;
    std::io::stdin().read_to_string(&mut src).expect("read stdin");
    let mut bytes = Vec::new();
    for tok in src.split_whitespace() {
        let w = u32::from_str_radix(tok.trim_start_matches("0x"), 16).expect("hex word");
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    match encode::decode_program(&bytes) {
        Ok(prog) => {
            print!("{}", asm::disassemble_program(&prog));
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    use power_mma::coordinator::{Coordinator, CoordinatorConfig, MlpWeights, Payload};
    use power_mma::runtime::{artifacts, det_input, Runtime};
    let cmd = Command::new("power-mma serve", "serve AOT models; run a self-test load")
        .opt("artifacts", Some("artifacts"), "artifact directory")
        .opt("requests", Some("1000"), "self-test request count");
    let m = parse_or_exit(cmd, args);
    let dir = m.get("artifacts").to_string();
    let n_req = m.get_usize("requests").unwrap();
    match artifacts::ensure_artifacts(std::path::Path::new(&dir)) {
        Ok(true) => eprintln!("materialized embedded AOT artifacts into {dir}/"),
        Ok(false) => {}
        Err(e) => {
            eprintln!("cannot prepare artifact directory {dir}: {e}");
            return 1;
        }
    }
    let cfg = CoordinatorConfig::default();
    let weights = MlpWeights::deterministic(&cfg);
    let features = cfg.features;
    let coord = Coordinator::start(cfg, weights, move || {
        let mut rt = Runtime::cpu(&dir)?;
        let names = rt.load_all()?;
        eprintln!("loaded models: {names:?} on {}", rt.platform());
        Ok(rt)
    });
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let f = det_input(features, i as u64 % 13);
        rxs.push(coord.submit(Payload::Classify { features: f }).1);
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    let stats = coord.shutdown();
    println!(
        "served {ok}/{n_req} requests in {:.2?} ({:.0} req/s); \
         p50 {} us, p99 {} us, mean batch occupancy {:.1}",
        dt,
        n_req as f64 / dt.as_secs_f64(),
        stats.latency.quantile_us(0.5),
        stats.latency.quantile_us(0.99),
        stats.mean_batch_occupancy()
    );
    if ok == n_req {
        0
    } else {
        1
    }
}

fn cmd_gen_artifacts(args: &[String]) -> i32 {
    use power_mma::runtime::artifacts;
    let cmd = Command::new(
        "power-mma gen-artifacts",
        "write the embedded AOT artifact set (HLO text + meta + expected outputs) to disk",
    )
    .opt("out", Some("artifacts"), "output directory");
    let m = parse_or_exit(cmd, args);
    let dir = std::path::PathBuf::from(m.get("out"));
    match artifacts::write_artifacts(&dir) {
        Ok(()) => {
            for a in artifacts::EMBEDDED {
                println!("  {}: {} chars of HLO text", a.name, a.hlo_text.len());
            }
            println!("wrote {} artifacts + manifest to {}", artifacts::EMBEDDED.len(), dir.display());
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
