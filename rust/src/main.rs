//! `power-mma` — command-line front end to the reproduction.
//!
//! Subcommands map to the paper's experiments and tools:
//!
//! * `fig10` / `fig11` / `fig12` — regenerate the evaluation figures;
//! * `hpl` — functional HPL (with `--backend sim-mma` every trailing MAC
//!   executes as simulated MMA instructions);
//! * `simulate` — time a kernel on a machine configuration;
//! * `asm` / `disasm` — the Power ISA MMA assembler/disassembler;
//! * `serve` — start the analytics coordinator on the AOT artifacts
//!   (materializing the embedded set when the directory is empty) and run
//!   a self-test load on the native plan backend;
//! * `bench serve` — measure compiled-plan execution vs the legacy
//!   interpreter walk and blocked vs reference GEMM across worker counts,
//!   emitting a machine-readable `BENCH_runtime.json`;
//! * `gen-artifacts` — write the embedded AOT artifact set to disk.

use power_mma::benchkit::f2;
use power_mma::blas::gemm::{RefGemm, SimMmaGemm};
use power_mma::cli::Command;
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::hpl::{hpl_cycles, hpl_run, CycleCost, Setup};
use power_mma::isa::asm;
use power_mma::isa::encode;
use power_mma::kernels::dgemm::dgemm_8xnx8_program;
use power_mma::kernels::vsx::vsx_dgemm_8x4_program;
use power_mma::metrics::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("fig10") => cmd_fig10(&args[1..]),
        Some("fig11") => cmd_fig11(&args[1..]),
        Some("fig12") => cmd_fig12(&args[1..]),
        Some("hpl") => cmd_hpl(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("asm") => cmd_asm(&args[1..]),
        Some("disasm") => cmd_disasm(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("profile") => cmd_profile(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("gen-artifacts") => cmd_gen_artifacts(&args[1..]),
        _ => {
            eprintln!(
                "power-mma — reproduction of 'A matrix math facility for Power ISA processors'\n\n\
                 usage: power-mma <command> [options]\n\n\
                 commands:\n\
                 \x20 fig10     HPL flops/cycle vs problem size (paper Figure 10)\n\
                 \x20 fig11     DGEMM flops/cycle vs N (paper Figure 11)\n\
                 \x20 fig12     average power of 128x128 DGEMM (paper Figure 12)\n\
                 \x20 hpl       functional HPL run with residual check\n\
                 \x20 simulate  time a kernel on a machine model\n\
                 \x20 asm       assemble MMA assembly to bytes\n\
                 \x20 disasm    disassemble bytes to MMA assembly\n\
                 \x20 serve     serve the AOT models and run a self-test load\n\
                 \x20 profile   per-step roofline profile of a compiled model plan\n\
                 \x20 bench     runtime benchmarks (bench serve -> BENCH_runtime.json)\n\
                 \x20 gen-artifacts  write the embedded AOT artifact set to disk\n\n\
                 run `power-mma <command> --help` for options"
            );
            2
        }
    };
    std::process::exit(code);
}

fn parse_or_exit(cmd: Command, args: &[String]) -> power_mma::cli::Matches {
    match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn cmd_fig10(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma fig10", "HPL flops/cycle vs N (Figure 10)")
        .opt("sizes", Some("512,1024,2048,4096,8192"), "problem sizes to sweep")
        .opt("nb", Some("128"), "LU panel width");
    let m = parse_or_exit(cmd, args);
    let sizes = m.get_usize_list("sizes").unwrap();
    let nb = m.get_usize("nb").unwrap();
    let mut table = Table::new(&["N", "POWER9", "POWER10-VSX", "POWER10-MMA", "MMA/P9"]);
    let mut costs: Vec<CycleCost> = Setup::ALL.iter().map(|&s| CycleCost::new(s)).collect();
    for &n in &sizes {
        let mut row = vec![n.to_string()];
        let mut vals = Vec::new();
        for (i, &setup) in Setup::ALL.iter().enumerate() {
            let t = hpl_cycles(setup, n, nb, &mut costs[i]);
            vals.push(t.flops_per_cycle());
            row.push(f2(t.flops_per_cycle()));
        }
        row.push(f2(vals[2] / vals[0]));
        table.row(&row);
    }
    println!("HPL performance (flops/cycle), paper Figure 10:\n{}", table.render());
    0
}

fn cmd_fig11(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma fig11", "DGEMM Nx128 * 128xN flops/cycle (Figure 11)")
        .opt("sizes", Some("128,256,512,1024,2048,4096"), "N values");
    let m = parse_or_exit(cmd, args);
    let sizes = m.get_usize_list("sizes").unwrap();
    let mut table =
        Table::new(&["N", "POWER9", "POWER10-VSX", "POWER10-MMA", "MMA/VSX", "MMA/P9"]);
    let mut costs: Vec<CycleCost> = Setup::ALL.iter().map(|&s| CycleCost::new(s)).collect();
    for &n in &sizes {
        let mut vals = Vec::new();
        for (i, _) in Setup::ALL.iter().enumerate() {
            let cycles = costs[i].dgemm_cycles(n, n, 128);
            let flops = 2.0 * (n * n * 128) as f64;
            vals.push(flops / cycles as f64);
        }
        table.row(&[
            n.to_string(),
            f2(vals[0]),
            f2(vals[1]),
            f2(vals[2]),
            f2(vals[2] / vals[1]),
            f2(vals[2] / vals[0]),
        ]);
    }
    println!("DGEMM performance (flops/cycle), paper Figure 11:\n{}", table.render());
    0
}

fn cmd_fig12(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma fig12", "average power of 128x128 DGEMM (Figure 12)")
        .flag("gate-mme", "power-gate the MME during VSX runs");
    let m = parse_or_exit(cmd, args);
    let gate = m.flag("gate-mme");
    let mut table =
        Table::new(&["config", "CORE w/o MME", "MME", "TOTAL", "flops/cycle", "power/flop"]);
    for setup in Setup::ALL {
        let mut cost = CycleCost::new(setup);
        if gate {
            cost.sim_mut().set_mme_gated(true);
        }
        let r = cost.kernel_report(128);
        let e = &r.energy;
        table.row(&[
            setup.label().to_string(),
            f2(e.core_power),
            f2(e.mme_power),
            f2(e.total_power),
            f2(r.flops_per_cycle()),
            format!("{:.3}", e.total_power / r.flops_per_cycle()),
        ]);
    }
    println!(
        "Average power draw of 128x128 DGEMM (arbitrary units), paper Figure 12{}:\n{}",
        if gate { " (MME power-gated)" } else { "" },
        table.render()
    );
    0
}

fn cmd_hpl(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma hpl", "functional HPL with residual check")
        .opt("n", Some("256"), "problem size")
        .opt("nb", Some("64"), "panel width")
        .opt("backend", Some("reference"), "trailing-update backend: reference | sim-mma")
        .opt("seed", Some("42"), "matrix seed");
    let m = parse_or_exit(cmd, args);
    let n = m.get_usize("n").unwrap();
    let nb = m.get_usize("nb").unwrap();
    let seed = m.get_u64("seed").unwrap();
    let r = match m.get("backend") {
        "sim-mma" => {
            let mut b = SimMmaGemm::default();
            let r = hpl_run(n, nb, seed, &mut b).unwrap();
            println!(
                "trailing updates executed as MMA instruction streams: {} instructions, {} gers",
                b.stats.instructions, b.stats.mma_instructions
            );
            r
        }
        _ => hpl_run(n, nb, seed, &mut RefGemm).unwrap(),
    };
    println!(
        "HPL N={n} NB={nb}: residual {:.3e} -> {}",
        r.residual,
        if r.passed() { "PASSED" } else { "FAILED" }
    );
    println!(
        "nominal {:.3} Gflop; gemm fraction {:.1}%",
        r.nominal_flops() / 1e9,
        100.0 * r.profile.gemm_flops as f64 / r.profile.total_flops() as f64
    );
    if r.passed() {
        0
    } else {
        1
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma simulate", "time a kernel on a machine model")
        .opt("machine", Some("power10"), "power9 | power10")
        .opt("k", Some("128"), "inner dimension of the kernel")
        .positional("kernel", "dgemm-mma | dgemm-vsx");
    let m = parse_or_exit(cmd, args);
    let k = m.get_usize("k").unwrap();
    let cfg = match m.get("machine") {
        "power9" => MachineConfig::power9(),
        _ => MachineConfig::power10(),
    };
    let prog = match m.positional(0) {
        "dgemm-mma" => dgemm_8xnx8_program(k),
        "dgemm-vsx" => vsx_dgemm_8x4_program(k),
        other => {
            eprintln!("unknown kernel {other}");
            return 2;
        }
    };
    let mut sim = CoreSim::new(cfg);
    let r = sim.run(&prog, 1 << 26);
    println!(
        "{} on {}: {} insts, {} cycles, {:.2} flops/cycle (ipc {:.2})",
        m.positional(0),
        r.name,
        r.instructions,
        r.cycles,
        r.flops_per_cycle(),
        r.ipc()
    );
    println!(
        "units: vsu={} mma={} lsu={} fx={} | cache: l1={} l2={} miss={}",
        r.units.vsu_ops, r.units.mma_ops, r.units.lsu_ops, r.units.fx_ops, r.l1_hits, r.l2_hits, r.mem_misses
    );
    0
}

fn cmd_asm(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma asm", "assemble MMA assembly (stdin) to hex")
        .flag("bytes", "print raw bytes instead of words");
    let m = parse_or_exit(cmd, args);
    let mut src = String::new();
    use std::io::Read;
    std::io::stdin().read_to_string(&mut src).expect("read stdin");
    match asm::assemble(&src) {
        Ok(prog) => {
            let bytes = encode::encode_program(&prog).expect("encode");
            if m.flag("bytes") {
                for b in &bytes {
                    print!("{b:02x} ");
                }
                println!();
            } else {
                for w in bytes.chunks_exact(4) {
                    println!("{:08x}", u32::from_le_bytes(w.try_into().unwrap()));
                }
            }
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_disasm(args: &[String]) -> i32 {
    let cmd = Command::new("power-mma disasm", "disassemble hex words (stdin, one per line)");
    let _m = parse_or_exit(cmd, args);
    let mut src = String::new();
    use std::io::Read;
    std::io::stdin().read_to_string(&mut src).expect("read stdin");
    let mut bytes = Vec::new();
    for tok in src.split_whitespace() {
        let w = u32::from_str_radix(tok.trim_start_matches("0x"), 16).expect("hex word");
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    match encode::decode_program(&bytes) {
        Ok(prog) => {
            print!("{}", asm::disassemble_program(&prog));
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    use power_mma::blas::bf16_gemm::Bf16Accum;
    use power_mma::coordinator::{
        Coordinator, CoordinatorConfig, MlpWeights, Payload, ShardRouting,
    };
    use power_mma::runtime::{artifacts, det_input, Device, EngineBackend, HloPlanBackend, Runtime};
    let cmd = Command::new("power-mma serve", "serve AOT models; run a self-test load")
        .opt("artifacts", Some("artifacts"), "artifact directory")
        .opt(
            "requests",
            Some("1000"),
            "self-test request count (a classify/DFT mix: every 4th request \
             exercises the second served family)",
        )
        .opt("threads", Some("0"), "device GEMM worker budget (0 = auto)")
        .opt("shards", Some("1"), "coordinator engine shards (share one device pool)")
        .opt(
            "routing",
            Some("round-robin"),
            "request->shard policy: round-robin (the self-test load is a single \
             model family, so this default lets --shards scale it) | sticky \
             (hash the model name to a shard — the library default, keeps a \
             model's plan buffers hot under mixed traffic)",
        )
        .opt(
            "buckets",
            Some("1,8,32"),
            "batch-bucket ladder: each entry compiles an mlp_b{m} and a \
             dft_b{m} plan; each family's batcher executes its window in the \
             smallest bucket >= its rows",
        )
        .opt("window-us", Some("2000"), "batching window (deadline for partial batches)")
        .opt("queue-cap", Some("1024"), "bounded submission queue depth per shard")
        .opt(
            "bf16-accum",
            Some("widened"),
            "bf16 dot accumulation contract: widened (f64 image, default) | \
             f32-pairs (f32 chain over k-pairs, the MMA rank-2 update order)",
        )
        .opt(
            "dtype",
            Some("f32"),
            "serving dtype: f32 (default) | int8 (calibrated quantized serving: \
             every bucket's dots run on the rank-4 xvi8ger4 integer engine, \
             quantize->dot->dequantize fused into one plan step)",
        )
        .flag(
            "no-tune",
            "skip the microkernel autotuner: every dot compiles to the \
             deterministic per-dtype heuristic variant instead of measuring \
             candidates on first sight of a shape class",
        )
        .opt(
            "tune-cache",
            Some(""),
            "persist the autotuner table across restarts: load measured rows \
             from this file before serving (a corrupt or version-mismatched \
             cache is ignored — classes re-measure), and write the table \
             back on shutdown",
        );
    let m = parse_or_exit(cmd, args);
    let dir = m.get("artifacts").to_string();
    let n_req = m.get_usize("requests").unwrap();
    let threads = m.get_usize("threads").unwrap();
    let shards = m.get_usize("shards").unwrap().max(1);
    let routing = match m.get("routing") {
        "sticky" => ShardRouting::ModelSticky,
        "round-robin" => ShardRouting::RoundRobin,
        other => {
            eprintln!("unknown --routing '{other}' (expected: sticky | round-robin)");
            return 2;
        }
    };
    let buckets = match m.get_usize_list("buckets") {
        Ok(b) if !b.is_empty() && b.iter().all(|&x| x > 0) => b,
        _ => {
            eprintln!("--buckets expects a non-empty list of positive batch sizes");
            return 2;
        }
    };
    let window = std::time::Duration::from_micros(m.get_u64("window-us").unwrap());
    let queue_cap = m.get_usize("queue-cap").unwrap().max(1);
    let accum = match m.get("bf16-accum") {
        "widened" => Bf16Accum::Widened,
        "f32-pairs" => Bf16Accum::F32Pairs,
        other => {
            eprintln!("unknown --bf16-accum '{other}' (expected: widened | f32-pairs)");
            return 2;
        }
    };
    let int8 = match m.get("dtype") {
        "f32" => false,
        "int8" => true,
        other => {
            eprintln!("unknown --dtype '{other}' (expected: f32 | int8)");
            return 2;
        }
    };
    let no_tune = m.flag("no-tune");
    let tune_cache = match m.get("tune-cache") {
        "" => None,
        p => Some(std::path::PathBuf::from(p)),
    };
    match artifacts::ensure_artifacts(std::path::Path::new(&dir)) {
        Ok(true) => eprintln!("materialized embedded AOT artifacts into {dir}/"),
        Ok(false) => {}
        Err(e) => {
            eprintln!("cannot prepare artifact directory {dir}: {e}");
            return 1;
        }
    }
    let cfg = CoordinatorConfig {
        shards,
        routing,
        buckets,
        max_delay: window,
        queue_cap,
        ..Default::default()
    };
    let ladder = cfg.ladder();
    let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
    let weights = MlpWeights::deterministic(&cfg);
    let features = cfg.features;
    let dft_n = cfg.dft_n;
    // one device = one persistent GEMM pool + budget, shared by every
    // shard (shards add engines, not worker threads)
    let device = if threads == 0 { Device::shared() } else { Device::new(threads) };
    // warm-start the autotuner from a previous run's measured rows: the
    // first shard's bucket compiles then hit memoized classes instead of
    // re-measuring. A corrupt/mismatched cache is a warning, not a fault.
    if let Some(path) = tune_cache.as_deref().filter(|_| !no_tune) {
        if path.exists() {
            match device.tune().load_into(path) {
                Ok(rows) => eprintln!("tune cache: loaded {rows} measured rows from {}", path.display()),
                Err(e) => eprintln!("tune cache: ignoring {} ({e}); classes will re-measure", path.display()),
            }
        }
    }
    let tune_table = device.tune();
    let coord = Coordinator::start(cfg, weights, move |shard| {
        // one tune table per device: shape classes measured by any shard's
        // compile are reused verbatim by every later shard/bucket compile
        let mut backend = if int8 {
            HloPlanBackend::int8()
        } else {
            HloPlanBackend::with_bf16_accum(accum)
        };
        if !no_tune {
            backend = backend.with_tuning(device.tune());
        }
        let backend: Box<dyn EngineBackend> = Box::new(backend);
        let mut rt = Runtime::with_device(device.clone(), backend, &dir);
        // int8: the calibrated buckets load *first* so their metas win
        // the bucket names over the record-less mlp_b32 disk fixture
        // (loads are idempotent by name)
        let int8_buckets =
            if int8 { rt.load_mlp_buckets_int8(&ladder, feat, hid, cls)? } else { Vec::new() };
        let names = rt.load_all()?;
        let bucket_names = if int8 {
            int8_buckets
        } else {
            rt.load_mlp_buckets(&ladder, feat, hid, cls)?
        };
        // the second served family: the same bucket ladder compiled as
        // fused dft_gemm plans (f32 regardless of --dtype — the DFT
        // family has no quantized contract)
        let dft_names = rt.load_dft_buckets(&ladder)?;
        eprintln!(
            "shard {shard}: loaded models {names:?} + buckets {bucket_names:?} + \
             dft {dft_names:?} on {} ({} pool workers, dtype {})",
            rt.platform(),
            rt.device().threads(),
            if int8 { "int8" } else { "f32" }
        );
        Ok(rt)
    });
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        // two-family self-test mix: every 4th request is a DFT transform,
        // the rest classify — both batchers fill independently
        let payload = if i % 4 == 3 {
            Payload::Dft {
                re: det_input(dft_n, i as u64 % 13),
                im: det_input(dft_n, (i as u64 + 1) % 13),
            }
        } else {
            Payload::Classify { features: det_input(features, i as u64 % 13) }
        };
        rxs.push(coord.submit(payload).1);
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    let stats = coord.shutdown();
    println!(
        "served {ok}/{n_req} requests in {:.2?} ({:.0} req/s, {shards} shard(s), {} routing); \
         p50 {} us, p99 {} us, mean batch occupancy {:.1}",
        dt,
        n_req as f64 / dt.as_secs_f64(),
        if routing == ShardRouting::RoundRobin { "round-robin" } else { "sticky" },
        stats.latency.quantile_us(0.5),
        stats.latency.quantile_us(0.99),
        stats.mean_batch_occupancy()
    );
    // per-family latency slices: the batched families fill their own
    // histograms next to the global one, so family tails are visible
    // (a DFT p99 regression no longer hides inside the classify bulk)
    for (family, h) in [
        ("mlp", &stats.latency_mlp),
        ("dft", &stats.latency_dft),
        ("direct", &stats.latency_direct),
    ] {
        if h.count() == 0 {
            continue;
        }
        println!(
            "  {family:6} latency: {:6} samples, p50 {} us, p95 {} us, p99 {} us",
            h.count(),
            h.quantile_us(0.5),
            h.quantile_us(0.95),
            h.quantile_us(0.99),
        );
    }
    for (family, buckets) in [("mlp", &stats.buckets), ("dft", &stats.dft_buckets)] {
        for b in buckets {
            println!(
                "  {family} bucket {:3}: {:5} flushes ({} full, {} deadline, {} shutdown), \
                 {} rows, occupancy {:.2}",
                b.bucket,
                b.flushes(),
                b.full.get(),
                b.deadline.get(),
                b.shutdown.get(),
                b.rows.get(),
                b.occupancy()
            );
        }
    }
    if let Some(path) = tune_cache.as_deref().filter(|_| !no_tune) {
        match tune_table.save(path) {
            Ok(rows) => eprintln!("tune cache: wrote {rows} measured rows to {}", path.display()),
            Err(e) => eprintln!("tune cache: cannot write {}: {e}", path.display()),
        }
    }
    if ok == n_req {
        0
    } else {
        1
    }
}

/// `power-mma profile <model>`: compile one AOT artifact to a plan and
/// print its per-step roofline — for every compiled step, the
/// synthesized MMA instruction stream's mix, the CoreSim-simulated
/// MACs/cycle ceiling on POWER10, the dtype's Table-I architectural
/// peak, and (unless `--no-measure`) achieved MACs/cycle from a
/// wall-clock replay of the step's executed kernel.
fn cmd_profile(args: &[String]) -> i32 {
    use power_mma::runtime::{artifacts, ModelMeta, TuneTable, NOMINAL_GHZ};
    let cmd = Command::new(
        "power-mma profile",
        "per-step roofline profile of a compiled model plan",
    )
    .opt("artifacts", Some("artifacts"), "artifact directory")
    .flag(
        "no-tune",
        "compile with the per-dtype heuristic variants (skip autotuner measurement)",
    )
    .flag("no-measure", "skip the wall-clock achieved replays (pure simulation)")
    .flag(
        "int8",
        "compile with the model's calibration record when it has one \
         (dots lower to the quantized rank-4 engine)",
    )
    .positional("model", "artifact name from manifest.txt, e.g. mlp_b32 | gemm_bf16 | dft_b32");
    let m = parse_or_exit(cmd, args);
    let model = m.positional(0).to_string();
    if model.is_empty() {
        eprintln!("profile: missing <model> (see `power-mma profile --help`)");
        return 2;
    }
    let dir = std::path::PathBuf::from(m.get("artifacts"));
    match artifacts::ensure_artifacts(&dir) {
        Ok(true) => eprintln!("materialized embedded AOT artifacts into {}/", dir.display()),
        Ok(false) => {}
        Err(e) => {
            eprintln!("cannot prepare artifact directory {}: {e}", dir.display());
            return 1;
        }
    }
    let manifest = match std::fs::read_to_string(dir.join("manifest.txt")) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {}/manifest.txt: {e}", dir.display());
            return 1;
        }
    };
    let mut meta: Option<ModelMeta> = None;
    for line in manifest.lines().filter(|l| !l.trim().is_empty()) {
        match ModelMeta::parse(line) {
            Ok(mm) if mm.name == model => {
                meta = Some(mm);
                break;
            }
            Ok(_) => {}
            Err(e) => {
                eprintln!("bad manifest line: {e}");
                return 1;
            }
        }
    }
    let Some(meta) = meta else {
        eprintln!("unknown model '{model}' (not in {}/manifest.txt)", dir.display());
        return 1;
    };
    let hlo_path = dir.join(format!("{model}.hlo.txt"));
    let hlo_text = match std::fs::read_to_string(&hlo_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {}: {e}", hlo_path.display());
            return 1;
        }
    };
    let mut opts = power_mma::runtime::plan::PlanOptions::default();
    if !m.flag("no-tune") {
        opts.tune = Some(std::sync::Arc::new(TuneTable::new()));
    }
    if m.flag("int8") {
        if meta.calib.is_none() {
            eprintln!("model '{model}' has no calibration record; cannot profile --int8");
            return 1;
        }
        opts.int8_calib = meta.calib.clone();
    }
    let plan = match power_mma::runtime::hlo::HloModule::parse(&hlo_text)
        .and_then(|mm| power_mma::runtime::plan::Plan::compile_with_options(&mm, opts))
    {
        Ok(p) => p,
        Err(e) => {
            eprintln!("compiling plan for {model}: {e}");
            return 1;
        }
    };
    let measure = !m.flag("no-measure");
    let profiles = if measure { plan.profile_measured() } else { plan.profile() };
    let mut table = Table::new(&[
        "#", "step", "dtype", "m", "n", "k", "variant", "insts", "macs", "loads", "stores",
        "ceil", "peak", "ach", "%ceil", "bound", "top opcodes",
    ]);
    let mut total_macs = 0u64;
    for p in &profiles {
        total_macs += p.mix.macs;
        let (ceil, peak, ach, pct) = if p.is_gemm() {
            (
                f2(p.sim_macs_per_cycle),
                format!("{:.0}", p.table1_peak_macs_per_cycle),
                p.achieved_macs_per_cycle.map(|a| format!("{a:.3}")).unwrap_or("-".into()),
                p.pct_of_ceiling().map(|x| format!("{:.1}%", x * 100.0)).unwrap_or("-".into()),
            )
        } else {
            ("-".into(), "-".into(), "-".into(), "-".into())
        };
        table.row(&[
            p.index.to_string(),
            p.step.clone(),
            p.dtype.to_string(),
            p.m.to_string(),
            p.n.to_string(),
            p.k.to_string(),
            p.variant.map(|v| v.name()).unwrap_or_else(|| "-".into()),
            p.mix.insts.to_string(),
            p.mix.macs.to_string(),
            p.mix.loads.to_string(),
            p.mix.stores.to_string(),
            ceil,
            peak,
            ach,
            pct,
            p.bound.to_string(),
            p.mix.top_opcodes(3),
        ]);
    }
    println!(
        "{model}: {} steps, {total_macs} MACs per request; simulated on power10, \
         achieved at {NOMINAL_GHZ:.0} GHz nominal{}:\n{}",
        profiles.len(),
        if measure { "" } else { " (measurement off)" },
        table.render()
    );
    for p in &profiles {
        if p.is_gemm() {
            let occ = p
                .occupancies
                .iter()
                .map(|(u, f)| format!("{u} {:.0}%", f * 100.0))
                .collect::<Vec<_>>()
                .join(", ");
            println!("  step {:2} {}: occupancy {occ}", p.index, p.step);
        }
    }
    0
}

/// HLO text of a single `n×n×n` f32 dot — the synthetic artifact used to
/// benchmark plan-vs-interpreter execution at paper-evaluation sizes.
fn gemm_hlo_text(n: usize) -> String {
    format!(
        "HloModule bench_gemm_{n}\n\n\
         ENTRY main.5 {{\n\
         \x20 Arg_0.1 = f32[{n},{n}]{{1,0}} parameter(0)\n\
         \x20 Arg_1.2 = f32[{n},{n}]{{1,0}} parameter(1)\n\
         \x20 dot.3 = f32[{n},{n}]{{1,0}} dot(Arg_0.1, Arg_1.2), \
         lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 ROOT tuple.4 = (f32[{n},{n}]{{1,0}}) tuple(dot.3)\n\
         }}\n"
    )
}

/// Parameters of one coordinator end-to-end measurement.
struct CoordBenchOpts {
    /// Short tag used for the scratch artifact directory + log lines.
    label: String,
    n_req: usize,
    shards: usize,
    routing: power_mma::coordinator::ShardRouting,
    /// Batch-bucket ladder handed to [`CoordinatorConfig::buckets`].
    buckets: Vec<usize>,
    /// Batching window ([`CoordinatorConfig::max_delay`]).
    window: std::time::Duration,
    /// Suppress the per-run stdout line (the sweep prints its own).
    quiet: bool,
}

/// One coordinator end-to-end measurement: the JSON fragment plus a
/// deterministic **numerics probe** (the classify response for a fixed
/// feature vector — each output row depends only on its own features, so
/// the probe must be bitwise identical across shard counts, bucket
/// ladders, and batch-mates) and the coordinator's own batching stats.
struct CoordBench {
    json: String,
    req_per_s: f64,
    p50_us: u64,
    p99_us: u64,
    probe: Vec<f32>,
    stats: std::sync::Arc<power_mma::coordinator::CoordStats>,
}

/// Drive the serving coordinator end-to-end over the **plan backend**
/// (router → continuous batcher → compiled bucket plans → pool-backed
/// blocked GEMM) on the embedded artifacts with `shards` engine threads
/// sharing the process device pool — the cross-PR end-to-end number of
/// `BENCH_runtime.json`, the shards=1-vs-2 comparison of the `pool`
/// block, and (swept over buckets/windows) the `batching` block.
fn bench_coordinator(opts: CoordBenchOpts) -> power_mma::error::Result<CoordBench> {
    let dir = std::env::temp_dir()
        .join(format!("mma-bench-coord-{}-{}", std::process::id(), opts.label));
    let result = bench_coordinator_in(&opts, &dir);
    std::fs::remove_dir_all(&dir).ok(); // clean up on every path
    result
}

fn bench_coordinator_in(
    opts: &CoordBenchOpts,
    dir: &std::path::Path,
) -> power_mma::error::Result<CoordBench> {
    use power_mma::coordinator::{Coordinator, CoordinatorConfig, MlpWeights, Payload};
    use power_mma::runtime::{artifacts, det_input, Runtime};
    use std::time::Instant;

    artifacts::ensure_artifacts(dir)?;
    let (n_req, shards) = (opts.n_req, opts.shards);
    let cfg = CoordinatorConfig {
        shards,
        routing: opts.routing,
        buckets: opts.buckets.clone(),
        max_delay: opts.window,
        ..Default::default()
    };
    let ladder = cfg.ladder();
    let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
    let weights = MlpWeights::deterministic(&cfg);
    let features = cfg.features;
    let dir2 = dir.to_path_buf(); // owned: the factory closure must be 'static
    let coord = Coordinator::start(cfg, weights, move |_shard| {
        let mut rt = Runtime::cpu(&dir2)?;
        rt.load_all()?;
        rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
        Ok(rt)
    });
    // warm up every shard: the first call per engine faults the plans in
    for _ in 0..shards.max(1) * 2 {
        let (_, rx) = coord.submit(Payload::Classify { features: det_input(features, 0) });
        rx.recv()
            .map_err(|_| power_mma::err!("coordinator warmup request dropped"))?
            .result
            .map_err(|e| power_mma::err!("coordinator warmup failed: {e}"))?;
    }
    // the numerics probe: a fixed feature vector whose response row must
    // not depend on shard count or batch-mates
    let (_, rx) = coord.submit(Payload::Classify { features: det_input(features, 1) });
    let probe = rx
        .recv()
        .map_err(|_| power_mma::err!("probe request dropped"))?
        .result
        .map_err(|e| power_mma::err!("probe request failed: {e}"))?;
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let f = det_input(features, i as u64 % 13);
        rxs.push(coord.submit(Payload::Classify { features: f }).1);
    }
    // per-request latencies of the *timed* requests only — the
    // coordinator's own histogram also holds the cold warmup requests,
    // which would otherwise dominate p99 in --quick runs
    let mut lat_us: Vec<u64> = Vec::with_capacity(n_req);
    for rx in rxs {
        if let Ok(r) = rx.recv() {
            if r.result.is_ok() {
                lat_us.push(r.latency.as_micros() as u64);
            }
        }
    }
    let dt = t0.elapsed();
    let stats = coord.shutdown();
    if lat_us.len() != n_req {
        power_mma::bail!("coordinator completed {}/{n_req} requests", lat_us.len());
    }
    lat_us.sort_unstable();
    let q = |f: f64| lat_us[((lat_us.len() - 1) as f64 * f) as usize];
    let (p50, p99) = (q(0.5), q(0.99));
    let req_s = n_req as f64 / dt.as_secs_f64();
    if !opts.quiet {
        println!(
            "coordinator e2e (plan backend, {shards} shard(s)): {n_req} requests -> \
             {req_s:.0} req/s, p50 {p50} us, p99 {p99} us, occupancy {:.1}",
            stats.mean_batch_occupancy()
        );
    }
    let json = format!(
        "{{\"backend\": \"native-hlo-plan\", \"shards\": {shards}, \"requests\": {n_req}, \
         \"req_per_s\": {req_s:.1}, \"p50_us\": {p50}, \"p99_us\": {p99}, \
         \"mean_batch_occupancy\": {:.2}}}",
        stats.mean_batch_occupancy()
    );
    Ok(CoordBench { json, req_per_s: req_s, p50_us: p50, p99_us: p99, probe, stats })
}

/// The `batching` block's identity bit: serve the **same** request set
/// once through the full bucket ladder (requests submitted in a burst so
/// windows batch and pad) and once with a buckets=[1] ladder (every
/// request executes as a singleton `mlp_b1` plan), and compare every
/// response bitwise. Each output row depends only on its own feature
/// row, so bucketization and padding must not change a single bit.
fn batching_identity_check(
    routing: power_mma::coordinator::ShardRouting,
) -> power_mma::error::Result<bool> {
    let dir =
        std::env::temp_dir().join(format!("mma-bench-batchid-{}", std::process::id()));
    let result = batching_identity_check_in(routing, &dir);
    std::fs::remove_dir_all(&dir).ok();
    result
}

fn batching_identity_check_in(
    routing: power_mma::coordinator::ShardRouting,
    dir: &std::path::Path,
) -> power_mma::error::Result<bool> {
    use power_mma::coordinator::{Coordinator, CoordinatorConfig, MlpWeights, Payload};
    use power_mma::runtime::{artifacts, det_input, Runtime};

    artifacts::ensure_artifacts(dir)?;
    let n = 48; // larger than the biggest bucket: forces at least one full flush
    let run = |buckets: Vec<usize>| -> power_mma::error::Result<Vec<Vec<f32>>> {
        let cfg = CoordinatorConfig { routing, buckets, ..Default::default() };
        let ladder = cfg.ladder();
        let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
        let weights = MlpWeights::deterministic(&cfg);
        let features = cfg.features;
        let dir2 = dir.to_path_buf();
        let coord = Coordinator::start(cfg, weights, move |_shard| {
            let mut rt = Runtime::cpu(&dir2)?;
            rt.load_all()?;
            rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
            Ok(rt)
        });
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            let f = det_input(features, i as u64);
            rxs.push(coord.submit(Payload::Classify { features: f }).1);
        }
        let mut outs = Vec::with_capacity(n);
        for rx in rxs {
            let r = rx.recv().map_err(|_| power_mma::err!("identity request dropped"))?;
            outs.push(r.result.map_err(|e| power_mma::err!("identity request failed: {e}"))?);
        }
        coord.shutdown();
        Ok(outs)
    };
    let batched = run(CoordinatorConfig::default().buckets)?;
    let singleton = run(vec![1])?;
    Ok(batched.len() == singleton.len()
        && batched.iter().zip(&singleton).all(|(x, y)| {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }))
}

/// Bitwise f32 oracle for the batched 16-point serving DFT under the
/// interpreter accumulation contract: each of the four real dots
/// accumulates its products in f64 in ascending k and narrows once to
/// f32; the ± combine then happens in f32 — the exact arithmetic of both
/// the fused `dft_gemm` step and the interpreter's lowered graph.
/// Row-major request layout (`re[r*n + k]`); returns the stacked
/// `[2*batch, n]` artifact layout (yr rows then yi rows).
fn dft_oracle(re: &[f32], im: &[f32], batch: usize, n: usize) -> Vec<f32> {
    assert_eq!(n, 16, "the serving DFT family is fixed at n=16");
    let (fr, fi) = power_mma::kernels::dft::dft16_twiddles_f32();
    let dot = |x: &[f32], f: &[f32], j: usize| {
        let mut acc = 0f64;
        for k in 0..n {
            acc += x[k] as f64 * f[k * n + j] as f64;
        }
        acc as f32
    };
    let mut yr = Vec::with_capacity(2 * batch * n);
    let mut yi = Vec::with_capacity(batch * n);
    for r in 0..batch {
        let (xr, xi) = (&re[r * n..(r + 1) * n], &im[r * n..(r + 1) * n]);
        for j in 0..n {
            let neg = -1f32 * dot(xi, &fi, j);
            yr.push(dot(xr, &fr, j) + neg);
            yi.push(dot(xr, &fi, j) + dot(xi, &fr, j));
        }
    }
    yr.extend_from_slice(&yi);
    yr
}

/// One two-family (classify + DFT) coordinator measurement for the
/// `dft` bench block.
struct DftMixBench {
    req_per_s: f64,
    p50_us: u64,
    p99_us: u64,
    classify_requests: usize,
    dft_requests: usize,
    /// Every DFT response matched its per-request oracle row bitwise.
    rows_exact: bool,
    /// JSON cells for the DFT family's per-bucket flush counters.
    dft_bucket_cells: Vec<String>,
    mlp_throttled: u64,
    dft_throttled: u64,
}

/// Drive mixed two-family traffic (3 classify : 1 DFT, the `serve`
/// self-test shape) through one coordinator over the plan backend, with
/// live per-family admission policies so the per-family throttle
/// counters exist, and a bitwise oracle for every DFT response — each
/// response row depends only on its own request, so batching, padding,
/// and cross-family interleaving must not change a single bit.
fn dft_mix_bench(
    n_req: usize,
    routing: power_mma::coordinator::ShardRouting,
) -> power_mma::error::Result<DftMixBench> {
    let dir =
        std::env::temp_dir().join(format!("mma-bench-dftmix-{}", std::process::id()));
    let result = dft_mix_bench_in(n_req, routing, &dir);
    std::fs::remove_dir_all(&dir).ok();
    result
}

fn dft_mix_bench_in(
    n_req: usize,
    routing: power_mma::coordinator::ShardRouting,
    dir: &std::path::Path,
) -> power_mma::error::Result<DftMixBench> {
    use power_mma::coordinator::{
        Coordinator, CoordinatorConfig, MlpWeights, ModelPolicy, Payload,
    };
    use power_mma::runtime::{artifacts, det_input, Runtime};
    use std::time::Instant;

    artifacts::ensure_artifacts(dir)?;
    let base = CoordinatorConfig { routing, ..Default::default() };
    // never-tripping caps: the point is that each family's throttle
    // counter is tracked (and reads zero under a healthy mixed load)
    let cfg = CoordinatorConfig {
        policies: vec![
            ModelPolicy::capped(&base.mlp_model(), usize::MAX),
            ModelPolicy::capped(&base.dft_model(), usize::MAX),
        ],
        ..base
    };
    let ladder = cfg.ladder();
    let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
    let weights = MlpWeights::deterministic(&cfg);
    let features = cfg.features;
    let dft_n = cfg.dft_n;
    let (mlp_family, dft_family) = (cfg.mlp_model(), cfg.dft_model());
    let dir2 = dir.to_path_buf();
    let coord = Coordinator::start(cfg, weights, move |_shard| {
        let mut rt = Runtime::cpu(&dir2)?;
        rt.load_all()?;
        rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
        rt.load_dft_buckets(&ladder)?;
        Ok(rt)
    });
    // warm both families so the timed loop measures hot plans
    for warm in 0..2u64 {
        let payloads = [
            Payload::Classify { features: det_input(features, warm) },
            Payload::Dft { re: det_input(dft_n, warm), im: det_input(dft_n, warm + 1) },
        ];
        for p in payloads {
            let (_, rx) = coord.submit(p);
            rx.recv()
                .map_err(|_| power_mma::err!("dft-mix warmup request dropped"))?
                .result
                .map_err(|e| power_mma::err!("dft-mix warmup failed: {e}"))?;
        }
    }
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    for i in 0..n_req {
        if i % 4 == 3 {
            let re = det_input(dft_n, i as u64 % 13);
            let im = det_input(dft_n, (i as u64 + 1) % 13);
            let rx = coord.submit(Payload::Dft { re: re.clone(), im: im.clone() }).1;
            pending.push((rx, Some((re, im))));
        } else {
            let f = det_input(features, i as u64 % 13);
            pending.push((coord.submit(Payload::Classify { features: f }).1, None));
        }
    }
    let mut lat_us: Vec<u64> = Vec::with_capacity(n_req);
    let mut rows_exact = true;
    let (mut classify_requests, mut dft_requests) = (0usize, 0usize);
    for (rx, dft_in) in pending {
        let Ok(r) = rx.recv() else { continue };
        let Ok(out) = r.result else { continue };
        lat_us.push(r.latency.as_micros() as u64);
        match dft_in {
            Some((re, im)) => {
                dft_requests += 1;
                let want = dft_oracle(&re, &im, 1, dft_n);
                rows_exact &= out.len() == want.len()
                    && out.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits());
            }
            None => classify_requests += 1,
        }
    }
    let dt = t0.elapsed();
    let mlp_throttled = coord.throttled_for(&mlp_family).unwrap_or(0);
    let dft_throttled = coord.throttled_for(&dft_family).unwrap_or(0);
    let stats = coord.shutdown();
    if lat_us.len() != n_req {
        power_mma::bail!("dft-mix completed {}/{n_req} requests", lat_us.len());
    }
    lat_us.sort_unstable();
    let q = |f: f64| lat_us[((lat_us.len() - 1) as f64 * f) as usize];
    let dft_bucket_cells = stats
        .dft_buckets
        .iter()
        .map(|s| {
            format!(
                "{{\"bucket\": {}, \"flushes_full\": {}, \"flushes_deadline\": {}, \
                 \"flushes_shutdown\": {}, \"rows\": {}, \"occupancy\": {:.3}}}",
                s.bucket,
                s.full.get(),
                s.deadline.get(),
                s.shutdown.get(),
                s.rows.get(),
                s.occupancy()
            )
        })
        .collect();
    Ok(DftMixBench {
        req_per_s: n_req as f64 / dt.as_secs_f64(),
        p50_us: q(0.5),
        p99_us: q(0.99),
        classify_requests,
        dft_requests,
        rows_exact,
        dft_bucket_cells,
        mlp_throttled,
        dft_throttled,
    })
}

/// Execute a compiled model on f32 inputs through the typed API (the
/// bench-side bridge: wraps the inputs as [`TensorRef`]s with the meta
/// shapes and collects the f32 output).
fn run_model(
    model: &dyn power_mma::runtime::CompiledModel,
    ctx: &mut power_mma::runtime::ExecCtx<'_>,
    meta: &power_mma::runtime::ModelMeta,
    inputs: &[Vec<f32>],
) -> Vec<f32> {
    use power_mma::runtime::{TensorMut, TensorRef};
    let trefs: Vec<TensorRef<'_>> = inputs
        .iter()
        .zip(&meta.input_shapes)
        .map(|(d, s)| TensorRef::f32(d, s))
        .collect();
    let mut out = vec![0f32; meta.output_len()];
    let mut tm = TensorMut::f32(&mut out, &meta.output_shape);
    model.execute(ctx, &trefs, &mut tm).expect("model exec");
    out
}

fn cmd_bench(args: &[String]) -> i32 {
    use power_mma::benchkit::{bench_budget, black_box};
    use power_mma::blas::bf16_gemm::{
        gemm_bf16_packed_into, gemm_bf16_reference, gemm_bf16_reference_pairs, gemm_bf16_tuned_into,
        Bf16Accum, Bf16Scratch, Bf16Src,
    };
    use power_mma::blas::block_gemm::{
        gemm_f32_fused_into, gemm_f32_into, gemm_f32_tuned_into, Accum, Epilogue, GemmScratch,
        GemmVariant, PanelB, Par,
    };
    use power_mma::blas::gemm::ref_gemm;
    use power_mma::blas::i8_gemm::{
        gemm_i8_dequant_into, gemm_i8_dequant_reference, gemm_i8_dequant_tuned_into,
        gemm_i8_packed_into, I8Accum, I8Epilogue, I8Scratch, I8SrcA, I8SrcB, QuantParams,
    };
    use power_mma::coordinator::ShardRouting;
    use power_mma::isa::GerKind;
    use power_mma::kernels::dft::dft_reference;
    use power_mma::kernels::gemm_rp::gemm_i8_8x16;
    use power_mma::kernels::pack::{DftPanels, Im2colSpec};
    use power_mma::runtime::hlo::bf16_round;
    use power_mma::runtime::{
        artifacts, det_input, det_inputs, dft_hlo_text, microkernel_fpc, mlp_hlo_text,
        mlp_int8_calib, Device, EngineBackend, HloInterpreterBackend, HloPlanBackend, ModelMeta,
        TuneDtype, TuneEpi, TunePanel, TuneTable,
    };
    use std::time::Duration;

    let cmd = Command::new(
        "power-mma bench",
        "runtime benchmarks; emits a machine-readable JSON report",
    )
    .opt("out", Some("BENCH_runtime.json"), "output JSON path")
    .opt("size", Some("512"), "GEMM problem size N (NxNxN)")
    .opt("threads", Some(""), "worker counts to sweep (default 1,2,...,available)")
    .opt("budget-ms", Some("400"), "time budget per measurement")
    .opt(
        "routing",
        Some("round-robin"),
        "request->shard policy for the coordinator benches: round-robin \
         (default: the load is one model family, so this lets shards=2 \
         scale) | sticky (the library default path, exercised by CI)",
    )
    .flag("quick", "CI smoke mode (N=128, short budget)")
    .positional("target", "what to benchmark: serve");
    let m = parse_or_exit(cmd, args);
    if m.positional(0) != "serve" {
        eprintln!("unknown bench target '{}' (only: serve)", m.positional(0));
        return 2;
    }
    let routing = match m.get("routing") {
        "sticky" => ShardRouting::ModelSticky,
        "round-robin" => ShardRouting::RoundRobin,
        other => {
            eprintln!("unknown --routing '{other}' (expected: sticky | round-robin)");
            return 2;
        }
    };
    let routing_name = if routing == ShardRouting::ModelSticky { "sticky" } else { "round-robin" };
    let quick = m.flag("quick");
    let size = if quick { 128 } else { m.get_usize("size").unwrap() };
    let budget = Duration::from_millis(if quick { 60 } else { m.get_u64("budget-ms").unwrap() });
    let avail = Device::default_threads();
    let threads: Vec<usize> = if m.get("threads").is_empty() {
        let mut t = vec![1usize];
        while *t.last().unwrap() * 2 <= avail {
            t.push(t.last().unwrap() * 2);
        }
        if *t.last().unwrap() != avail {
            t.push(avail);
        }
        t
    } else {
        match m.get_usize_list("threads") {
            Ok(t) if !t.is_empty() && t.iter().all(|&x| x > 0) => t,
            _ => {
                eprintln!("--threads expects a non-empty list of positive integers");
                return 2;
            }
        }
    };

    // -- 1. raw GEMM: legacy interpreter dot path vs blocked kernel ------
    let a = det_input(size * size, 1);
    let b = det_input(size * size, 2);
    let flops = 2.0 * (size * size * size) as f64;
    let s_ref = bench_budget("ref_gemm(f64 widen)", budget, || {
        let af: Vec<f64> = a.iter().map(|&v| f64::from(v)).collect();
        let bf: Vec<f64> = b.iter().map(|&v| f64::from(v)).collect();
        let c = ref_gemm(&af, &bf, size, size, size);
        black_box(c.len());
    });
    let ref_ms = s_ref.median.as_secs_f64() * 1e3;
    println!(
        "gemm {size}^3  ref_gemm          {ref_ms:9.2} ms  {:7.2} GFLOP/s",
        flops / s_ref.median.as_secs_f64() / 1e9
    );
    let mut gemm_rows = vec![format!(
        "{{\"impl\": \"ref_gemm\", \"threads\": 1, \"ms\": {ref_ms:.3}, \"gflops\": {:.3}}}",
        flops / s_ref.median.as_secs_f64() / 1e9
    )];
    let mut c = vec![0f32; size * size];
    let mut scratch = GemmScratch::new();
    for &t in &threads {
        let s = bench_budget(&format!("blocked t={t}"), budget, || {
            gemm_f32_into(&mut c, &a, &b, size, size, size, t, &mut scratch);
            black_box(c[0]);
        });
        let ms = s.median.as_secs_f64() * 1e3;
        println!(
            "gemm {size}^3  blocked {t:2} thread  {ms:9.2} ms  {:7.2} GFLOP/s",
            flops / s.median.as_secs_f64() / 1e9
        );
        gemm_rows.push(format!(
            "{{\"impl\": \"blocked\", \"threads\": {t}, \"ms\": {ms:.3}, \"gflops\": {:.3}}}",
            flops / s.median.as_secs_f64() / 1e9
        ));
    }

    // -- 2. end-to-end: compiled plan vs legacy interpreter walk ---------
    let shared_dev = Device::shared();
    let hlo = gemm_hlo_text(size);
    let meta = ModelMeta {
        name: format!("bench_gemm_{size}"),
        input_shapes: vec![vec![size, size], vec![size, size]],
        output_shape: vec![size, size],
        calib: None,
    };
    let interp = match HloInterpreterBackend.compile(&shared_dev, &meta.name, &hlo, &meta) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("compile (interpreter) failed: {e}");
            return 1;
        }
    };
    let ins: Vec<Vec<f32>> = vec![a.clone(), b.clone()];
    let mut ctx = shared_dev.ctx();
    let s_interp = bench_budget("interpreter walk", budget, || {
        black_box(run_model(interp.as_ref(), &mut ctx, &meta, &ins).len());
    });
    let interp_ms = s_interp.median.as_secs_f64() * 1e3;
    println!("e2e  {size}^3  interpreter walk  {interp_ms:9.2} ms");
    let mut plan_rows = Vec::new();
    let mut best_plan_ms = f64::INFINITY;
    for &t in &threads {
        // one device per worker budget: the plan draws its GEMM workers
        // from the device pool of the executing context
        let dev = Device::new(t);
        let plan = match HloPlanBackend::new().compile(&dev, &meta.name, &hlo, &meta) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("compile (plan) failed: {e}");
                return 1;
            }
        };
        let mut ctx = dev.ctx();
        let s = bench_budget(&format!("plan t={t}"), budget, || {
            black_box(run_model(plan.as_ref(), &mut ctx, &meta, &ins).len());
        });
        let ms = s.median.as_secs_f64() * 1e3;
        best_plan_ms = best_plan_ms.min(ms);
        println!(
            "e2e  {size}^3  plan {t:2} thread     {ms:9.2} ms  ({:.2}x vs interpreter)",
            interp_ms / ms
        );
        plan_rows.push(format!("{{\"threads\": {t}, \"ms\": {ms:.3}}}"));
    }
    let speedup = interp_ms / best_plan_ms;

    // -- 3. embedded fixtures: plan numerics + latency vs interpreter ----
    let mut fixture_rows = Vec::new();
    let mut all_identical = true;
    for art in artifacts::EMBEDDED {
        let meta = match ModelMeta::parse(art.meta) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{}: bad meta: {e}", art.name);
                return 1;
            }
        };
        let interp = HloInterpreterBackend.compile(&shared_dev, art.name, art.hlo_text, &meta);
        let plan = HloPlanBackend::new().compile(&shared_dev, art.name, art.hlo_text, &meta);
        let (interp, plan) = match (interp, plan) {
            (Ok(i), Ok(p)) => (i, p),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("{}: compile failed: {e}", art.name);
                return 1;
            }
        };
        let inputs = det_inputs(&meta);
        let mut ctx = shared_dev.ctx();
        let iout = run_model(interp.as_ref(), &mut ctx, &meta, &inputs);
        let pout = run_model(plan.as_ref(), &mut ctx, &meta, &inputs);
        let identical = iout.len() == pout.len()
            && iout.iter().zip(&pout).all(|(x, y)| x.to_bits() == y.to_bits());
        all_identical &= identical;
        let fb = budget.min(Duration::from_millis(100));
        let si = bench_budget(&format!("{} interp", art.name), fb, || {
            black_box(run_model(interp.as_ref(), &mut ctx, &meta, &inputs).len());
        });
        let sp = bench_budget(&format!("{} plan", art.name), fb, || {
            black_box(run_model(plan.as_ref(), &mut ctx, &meta, &inputs).len());
        });
        let (ims, pms) = (si.median.as_secs_f64() * 1e3, sp.median.as_secs_f64() * 1e3);
        println!(
            "fixture {:<10} interpreter {ims:8.3} ms | plan {pms:8.3} ms | numerics {}",
            art.name,
            if identical { "identical" } else { "DIFFER" }
        );
        fixture_rows.push(format!(
            "{{\"name\": \"{}\", \"identical\": {identical}, \"interpreter_ms\": {ims:.4}, \"plan_ms\": {pms:.4}}}",
            art.name
        ));
    }

    // -- 4. plan shape: the rewrite pass must compile the conv fixture to
    //       a single fused im2col GEMM (≤ 10 steps with the I/O copies) --
    let Some(conv) = artifacts::EMBEDDED.iter().find(|a| a.name == "conv2d_k3") else {
        eprintln!("conv2d_k3 fixture missing from the embedded artifact set");
        return 1;
    };
    let conv_module = match power_mma::runtime::hlo::HloModule::parse(conv.hlo_text) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("conv2d_k3: parse failed: {e}");
            return 1;
        }
    };
    let conv_plan = match power_mma::runtime::plan::Plan::compile(&conv_module) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("conv2d_k3: plan compile failed: {e}");
            return 1;
        }
    };
    let conv_steps = conv_plan.num_steps();
    let conv_gemms =
        conv_plan.step_names().iter().filter(|&&s| s == "im2col_gemm").count();
    println!(
        "conv2d_k3 plan: {} instructions -> {conv_steps} steps ({conv_gemms} im2col GEMM), \
         {} arena slots",
        conv_module.num_instructions(),
        conv_plan.num_slots()
    );
    if conv_steps > 10 || conv_gemms != 1 {
        eprintln!(
            "conv2d_k3 must compile to a single im2col GEMM in <= 10 steps \
             (got {conv_steps} steps, {conv_gemms} fused GEMMs)"
        );
        return 1;
    }

    // -- 5. bf16: packed-panel engine vs the widened path ----------------
    // plan shape first: the gemm_bf16 fixture must fuse its convert
    // round-trips into a single packed dot_bf16 step (the acceptance bar
    // of the bf16 engine)
    let Some(bf16_art) = artifacts::EMBEDDED.iter().find(|a| a.name == "gemm_bf16") else {
        eprintln!("gemm_bf16 fixture missing from the embedded artifact set");
        return 1;
    };
    let bf16_plan = match power_mma::runtime::hlo::HloModule::parse(bf16_art.hlo_text)
        .and_then(|m| power_mma::runtime::plan::Plan::compile(&m))
    {
        Ok(p) => p,
        Err(e) => {
            eprintln!("gemm_bf16: plan compile failed: {e}");
            return 1;
        }
    };
    let bf16_names = bf16_plan.step_names();
    let plan_has_dot_bf16 = bf16_names.iter().any(|&s| s == "dot_bf16");
    println!(
        "gemm_bf16 plan: {} steps {bf16_names:?} ({})",
        bf16_plan.num_steps(),
        if plan_has_dot_bf16 { "convert fused into packing" } else { "NO dot_bf16 step" }
    );
    if !plan_has_dot_bf16 {
        eprintln!("gemm_bf16 must compile to a plan containing a dot_bf16 step");
        return 1;
    }
    // the pre-packed-engine serving path: round every element to the
    // bf16 grid (two output-sized sweeps), then run the f32 blocked GEMM
    let mut ar = vec![0f32; size * size];
    let mut br = vec![0f32; size * size];
    let mut c_bf16_widened = vec![0f32; size * size];
    let mut widened_scratch = GemmScratch::new();
    let s_bf16_widened = bench_budget("bf16 widened (round + f32 gemm)", budget, || {
        for (d, &v) in ar.iter_mut().zip(&a) {
            *d = bf16_round(v);
        }
        for (d, &v) in br.iter_mut().zip(&b) {
            *d = bf16_round(v);
        }
        gemm_f32_fused_into(
            &mut c_bf16_widened,
            &ar,
            PanelB::Matrix(&br),
            size,
            size,
            size,
            Accum::F64,
            Epilogue::None,
            Par::Pool(shared_dev.pool(), avail),
            &mut widened_scratch,
        );
        black_box(c_bf16_widened[0]);
    });
    // the packed path: rounding fused into the pair-interleaved packers,
    // half-width panels, same worker pool
    let mut c_bf16_packed = vec![0f32; size * size];
    let mut bf16_scratch = Bf16Scratch::new();
    let s_bf16_packed = bench_budget("bf16 packed panels", budget, || {
        gemm_bf16_packed_into(
            &mut c_bf16_packed,
            Bf16Src::F32(&a),
            Bf16Src::F32(&b),
            size,
            size,
            size,
            Bf16Accum::Widened,
            Par::Pool(shared_dev.pool(), avail),
            &mut bf16_scratch,
        );
        black_box(c_bf16_packed[0]);
    });
    let (bf16_widened_ms, bf16_packed_ms) = (
        s_bf16_widened.median.as_secs_f64() * 1e3,
        s_bf16_packed.median.as_secs_f64() * 1e3,
    );
    // bitwise identity: packed == widened == the elementwise-rounding
    // reference (all three must agree — the interpreter contract)
    let bf16_ref = gemm_bf16_reference(&a, &b, size, size, size);
    let bf16_identical = c_bf16_packed
        .iter()
        .zip(&c_bf16_widened)
        .zip(&bf16_ref)
        .all(|((x, y), z)| x.to_bits() == y.to_bits() && x.to_bits() == z.to_bits());
    // the F32Pairs serving-mode contract (serve --bf16-accum f32-pairs):
    // same packed panels, accumulation chained in f32 over k-pairs (the
    // MMA rank-2 update order) instead of the widened f64 image — its
    // own oracle, bitwise
    let mut c_bf16_pairs = vec![0f32; size * size];
    let s_bf16_pairs = bench_budget("bf16 packed panels (f32-pairs)", budget, || {
        gemm_bf16_packed_into(
            &mut c_bf16_pairs,
            Bf16Src::F32(&a),
            Bf16Src::F32(&b),
            size,
            size,
            size,
            Bf16Accum::F32Pairs,
            Par::Pool(shared_dev.pool(), avail),
            &mut bf16_scratch,
        );
        black_box(c_bf16_pairs[0]);
    });
    let bf16_pairs_ms = s_bf16_pairs.median.as_secs_f64() * 1e3;
    let pairs_ref = gemm_bf16_reference_pairs(&a, &b, size, size, size);
    let bf16_pairs_identical =
        c_bf16_pairs.iter().zip(&pairs_ref).all(|(x, y)| x.to_bits() == y.to_bits());
    // and end-to-end through the plan: the gemm_bf16 fixture compiled
    // with the F32Pairs plan option must match the pairs oracle bitwise
    // (this is exactly what a `--bf16-accum f32-pairs` serving engine
    // executes)
    let bf16_meta = match ModelMeta::parse(bf16_art.meta) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("gemm_bf16: bad meta: {e}");
            return 1;
        }
    };
    let pairs_model = match HloPlanBackend::with_bf16_accum(Bf16Accum::F32Pairs).compile(
        &shared_dev,
        bf16_art.name,
        bf16_art.hlo_text,
        &bf16_meta,
    ) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("gemm_bf16: F32Pairs plan compile failed: {e}");
            return 1;
        }
    };
    let bf16_inputs = det_inputs(&bf16_meta);
    let plan_pairs_out = {
        let mut ctx = shared_dev.ctx();
        run_model(pairs_model.as_ref(), &mut ctx, &bf16_meta, &bf16_inputs)
    };
    let (bf16_m, bf16_k) = (bf16_meta.input_shapes[0][0], bf16_meta.input_shapes[0][1]);
    let bf16_n = bf16_meta.input_shapes[1][1];
    let plan_pairs_ref =
        gemm_bf16_reference_pairs(&bf16_inputs[0], &bf16_inputs[1], bf16_m, bf16_n, bf16_k);
    let plan_pairs_identical = plan_pairs_out.len() == plan_pairs_ref.len()
        && plan_pairs_out
            .iter()
            .zip(&plan_pairs_ref)
            .all(|(x, y)| x.to_bits() == y.to_bits());
    // Table I modeled on the core simulator: the rank-2 bf16 kernel
    // retires 2x the MACs per instruction of xvf32ger, so at equal issue
    // rates the MACs/cycle ratio approaches 2. The probe is the profile
    // layer's generalized microkernel simulation (identical program,
    // simulator, and fuel as the inline closure it replaced —
    // tests/profile_engine.rs pins the reproduction bit-for-bit).
    let sim_steps = 64usize;
    let fpc_f32 = microkernel_fpc(GerKind::F32Ger, 2 * sim_steps);
    let fpc_bf16 = microkernel_fpc(GerKind::Bf16Ger2, sim_steps);
    let macs_ratio = fpc_bf16 / fpc_f32;
    println!(
        "bf16 {size}^3  widened {bf16_widened_ms:9.2} ms | packed {bf16_packed_ms:9.2} ms \
         ({:.2}x) | numerics {} | sim MACs/cycle f32 {:.2} -> bf16 {:.2} ({macs_ratio:.2}x)",
        bf16_widened_ms / bf16_packed_ms,
        if bf16_identical { "identical" } else { "DIFFER" },
        fpc_f32 / 2.0,
        fpc_bf16 / 2.0
    );
    println!(
        "bf16 {size}^3  f32-pairs {bf16_pairs_ms:9.2} ms | vs pairs oracle {} | \
         plan(F32Pairs) vs oracle {}",
        if bf16_pairs_identical { "identical" } else { "DIFFER" },
        if plan_pairs_identical { "identical" } else { "DIFFER" }
    );

    // -- 6. pool: scoped-spawn vs persistent-pool GEMM, bit-identical ----
    let mut c_scoped = vec![0f32; size * size];
    let mut c_pool = vec![0f32; size * size];
    let mut pool_scratch = GemmScratch::new();
    let s_scoped = bench_budget("gemm scoped-spawn", budget, || {
        gemm_f32_fused_into(
            &mut c_scoped,
            &a,
            PanelB::Matrix(&b),
            size,
            size,
            size,
            Accum::F64,
            Epilogue::None,
            Par::Scoped(avail),
            &mut pool_scratch,
        );
        black_box(c_scoped[0]);
    });
    let s_pool = bench_budget("gemm persistent-pool", budget, || {
        gemm_f32_fused_into(
            &mut c_pool,
            &a,
            PanelB::Matrix(&b),
            size,
            size,
            size,
            Accum::F64,
            Epilogue::None,
            Par::Pool(shared_dev.pool(), avail),
            &mut pool_scratch,
        );
        black_box(c_pool[0]);
    });
    let (scoped_ms, pool_ms) =
        (s_scoped.median.as_secs_f64() * 1e3, s_pool.median.as_secs_f64() * 1e3);
    let pool_gemm_identical =
        c_scoped.iter().zip(&c_pool).all(|(x, y)| x.to_bits() == y.to_bits());
    println!(
        "gemm {size}^3  scoped-spawn {scoped_ms:9.2} ms | persistent-pool {pool_ms:9.2} ms \
         ({:.2}x) | numerics {}",
        scoped_ms / pool_ms,
        if pool_gemm_identical { "identical" } else { "DIFFER" }
    );

    // -- 6b. int8: the rank-4 quantized serving engine (Table I's 4x) ----
    // plan shape first: the calibrated serving MLP must lower both its
    // dots onto the quantized engine (the acceptance bar of the int8
    // serving path behind `serve --dtype int8`)
    let (i8f, i8h, i8c) = (64usize, 128usize, 32usize);
    let int8_calib = mlp_int8_calib(i8f, i8h, i8c);
    let int8_plan = match power_mma::runtime::hlo::HloModule::parse(&mlp_hlo_text(
        32, i8f, i8h, i8c,
    ))
    .and_then(|m| {
        power_mma::runtime::plan::Plan::compile_with_options(
            &m,
            power_mma::runtime::plan::PlanOptions {
                int8_calib: Some(int8_calib),
                ..Default::default()
            },
        )
    }) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("int8 MLP: plan compile failed: {e}");
            return 1;
        }
    };
    let int8_names = int8_plan.step_names();
    let plan_has_dot_i8 = int8_names.iter().any(|s| s.starts_with("dot_i8"));
    println!(
        "int8 MLP plan: {} steps {int8_names:?} ({})",
        int8_plan.num_steps(),
        if plan_has_dot_i8 { "dots quantized" } else { "NO dot_i8 step" }
    );
    if !plan_has_dot_i8 {
        eprintln!("the calibrated MLP must compile to a plan containing dot_i8 steps");
        return 1;
    }
    // Machine-parity identity bit: the engine's wrapping rank-4 integer
    // dot vs the instruction-level xvi8ger4/pp chain on an 8x16 tile
    // (k % 4 != 0, so the zero-padded tail == pmsk-disabled lanes)
    let i8k = 27usize;
    let xq: Vec<i8> = (0..8 * i8k).map(|i| ((i * 37 + 11) % 256) as u8 as i8).collect();
    let yq: Vec<u8> = (0..i8k * 16).map(|i| ((i * 53 + 7) % 256) as u8).collect();
    let mut i8_tile = vec![0i32; 8 * 16];
    let mut i8_scratch = I8Scratch::new();
    gemm_i8_packed_into(
        &mut i8_tile,
        I8SrcA::Q(&xq),
        I8SrcB::Q(&yq),
        8,
        16,
        i8k,
        I8Accum::Wrapping,
        Par::Seq,
        &mut i8_scratch,
    );
    // the Machine oracle takes Y as 16 rows of k — transpose the panel
    let mut yt = vec![0u8; 16 * i8k];
    for r in 0..i8k {
        for j in 0..16 {
            yt[j * i8k + r] = yq[r * 16 + j];
        }
    }
    let machine_parity = match gemm_i8_8x16(&xq, &yt, i8k) {
        Ok(tile) => i8_tile == tile.iter().flatten().copied().collect::<Vec<i32>>(),
        Err(e) => {
            eprintln!("xvi8ger4 Machine oracle failed: {e:?}");
            return 1;
        }
    };
    // packed int8 vs the f32 pool GEMM at the same size: quantize both
    // f32 operands inside packing, integer dot, dequantize at writeback
    let i8_q =
        QuantParams { a_scale: 1.0 / 255.0, a_zp: 0, b_scale: 1.0 / 255.0, b_zp: 128 };
    let mut c_int8 = vec![0f32; size * size];
    let s_int8 = bench_budget("int8 packed panels (quantize+dequant fused)", budget, || {
        gemm_i8_dequant_into(
            &mut c_int8,
            &a,
            &b,
            size,
            size,
            size,
            &i8_q,
            I8Epilogue::None,
            Par::Pool(shared_dev.pool(), avail),
            &mut i8_scratch,
        );
        black_box(c_int8[0]);
    });
    let int8_ms = s_int8.median.as_secs_f64() * 1e3;
    // bitwise vs the engine's own scalar reference; accuracy vs the f32
    // pool result is quantization-grid error, reported, not a parity bar
    let i8_ref = gemm_i8_dequant_reference(&a, &b, size, size, size, &i8_q, None, false);
    let int8_ref_identical =
        c_int8.iter().zip(&i8_ref).all(|(x, y)| x.to_bits() == y.to_bits());
    let int8_identical = machine_parity && int8_ref_identical;
    let int8_max_err =
        c_int8.iter().zip(&c_pool).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    // Table I on the core simulator: xvi8ger4 retires 4x the MACs per
    // instruction of xvf32ger (equal-MACs programs, like the bf16 pair)
    let fpc_f32_4x = microkernel_fpc(GerKind::F32Ger, 4 * sim_steps);
    let fpc_i8 = microkernel_fpc(GerKind::I8Ger4, sim_steps);
    let int8_macs_ratio = fpc_i8 / fpc_f32_4x;
    println!(
        "int8 {size}^3  f32 {pool_ms:9.2} ms | packed {int8_ms:9.2} ms ({:.2}x) | \
         machine parity {} | max |err| vs f32 {int8_max_err:.5} | \
         sim MACs/cycle f32 {:.2} -> i8 {:.2} ({int8_macs_ratio:.2}x)",
        pool_ms / int8_ms,
        if int8_identical { "identical" } else { "DIFFER" },
        fpc_f32_4x / 2.0,
        fpc_i8 / 2.0
    );

    // -- 6c. autotuner: measure -> memoize -> bake into compiled plans ---
    // seed one device-style tune table through real plan compiles at two
    // batch points of the serving MLP (batch 1 decisively favors the
    // narrow 4x8 f32 tile: every microkernel computes all MR rows, so
    // mr=8 wastes 7/8 of the arithmetic at m=1), plus the bf16 fixture
    // and the calibrated int8 MLP so every dtype lands in the table
    let tune_table = std::sync::Arc::new(TuneTable::new());
    for batch in [1usize, 32] {
        let mlp = mlp_hlo_text(batch, i8f, i8h, i8c);
        let opts = power_mma::runtime::plan::PlanOptions {
            tune: Some(tune_table.clone()),
            ..Default::default()
        };
        if let Err(e) = power_mma::runtime::hlo::HloModule::parse(&mlp)
            .and_then(|m| power_mma::runtime::plan::Plan::compile_with_options(&m, opts))
        {
            eprintln!("autotune: MLP b{batch} plan compile failed: {e}");
            return 1;
        }
    }
    if let Err(e) = power_mma::runtime::hlo::HloModule::parse(bf16_art.hlo_text).and_then(|m| {
        power_mma::runtime::plan::Plan::compile_with_options(
            &m,
            power_mma::runtime::plan::PlanOptions {
                tune: Some(tune_table.clone()),
                ..Default::default()
            },
        )
    }) {
        eprintln!("autotune: gemm_bf16 plan compile failed: {e}");
        return 1;
    }
    if let Err(e) =
        power_mma::runtime::hlo::HloModule::parse(&mlp_hlo_text(32, i8f, i8h, i8c)).and_then(|m| {
            power_mma::runtime::plan::Plan::compile_with_options(
                &m,
                power_mma::runtime::plan::PlanOptions {
                    int8_calib: Some(mlp_int8_calib(i8f, i8h, i8c)),
                    tune: Some(tune_table.clone()),
                    ..Default::default()
                },
            )
        })
    {
        eprintln!("autotune: int8 MLP plan compile failed: {e}");
        return 1;
    }
    // -- 6d. roofline: per-step observability over the served families ---
    // one plan per served family, compiled against the same tune table
    // (the classes seeded above stay memoized; the DFT compile adds its
    // dft_packed class, which the tuning audit below then replays), then
    // every compiled GEMM step bridges through the profile layer:
    // executed kernel -> synthesized MMA stream -> CoreSim ceiling ->
    // achieved MACs/cycle from a wall-clock replay at the nominal clock
    let roofline_plans: Vec<(&str, power_mma::runtime::plan::Plan)> = {
        let compile = |text: &str, int8: Option<power_mma::runtime::Int8Calib>| {
            power_mma::runtime::hlo::HloModule::parse(text).and_then(|mm| {
                power_mma::runtime::plan::Plan::compile_with_options(
                    &mm,
                    power_mma::runtime::plan::PlanOptions {
                        tune: Some(tune_table.clone()),
                        int8_calib: int8,
                        ..Default::default()
                    },
                )
            })
        };
        let family_plans = [
            ("mlp_f32", compile(&mlp_hlo_text(32, i8f, i8h, i8c), None)),
            ("gemm_bf16", compile(bf16_art.hlo_text, None)),
            (
                "mlp_int8",
                compile(&mlp_hlo_text(32, i8f, i8h, i8c), Some(mlp_int8_calib(i8f, i8h, i8c))),
            ),
            ("dft_b32", compile(&dft_hlo_text(32), None)),
        ];
        let mut out = Vec::new();
        for (fam, p) in family_plans {
            match p {
                Ok(p) => out.push((fam, p)),
                Err(e) => {
                    eprintln!("roofline: {fam} plan compile failed: {e}");
                    return 1;
                }
            }
        }
        out
    };
    let mut roofline_rows = Vec::new();
    let mut roofline_in_range = true;
    let mut roofline_table = Table::new(&[
        "family", "step", "dtype", "m", "n", "k", "variant", "insts", "macs", "ceil", "ach",
        "%ceil", "bound",
    ]);
    for (fam, plan) in &roofline_plans {
        for p in plan.profile_measured() {
            if !p.is_gemm() {
                continue;
            }
            let achieved = p.achieved_macs_per_cycle.unwrap_or(0.0);
            let pct = p.pct_of_ceiling().unwrap_or(0.0);
            roofline_in_range &= pct > 0.0 && pct <= 1.05;
            let variant = p.variant.map(|v| v.name()).unwrap_or_default();
            roofline_table.row(&[
                fam.to_string(),
                p.step.clone(),
                p.dtype.to_string(),
                p.m.to_string(),
                p.n.to_string(),
                p.k.to_string(),
                variant.clone(),
                p.mix.insts.to_string(),
                p.mix.macs.to_string(),
                f2(p.sim_macs_per_cycle),
                format!("{achieved:.3}"),
                format!("{:.1}%", pct * 100.0),
                p.bound.to_string(),
            ]);
            let opcodes = p
                .mix
                .counts
                .iter()
                .map(|(name, c)| format!("\"{name}\": {c}"))
                .collect::<Vec<_>>()
                .join(", ");
            let occ = p
                .occupancies
                .iter()
                .map(|(u, f)| format!("\"{u}\": {f:.4}"))
                .collect::<Vec<_>>()
                .join(", ");
            roofline_rows.push(format!(
                "{{\"family\": \"{fam}\", \"step_index\": {}, \"step\": \"{}\", \
                 \"dtype\": \"{}\", \"m\": {}, \"n\": {}, \"k\": {}, \
                 \"variant\": \"{variant}\", \"gemms\": {}, \
                 \"mix\": {{\"insts\": {}, \"macs\": {}, \"loads\": {}, \"stores\": {}, \
                 \"load_bytes\": {}, \"store_bytes\": {}, \"acc_transfers\": {}, \
                 \"opcodes\": {{{opcodes}}}}}, \
                 \"sim_cycles\": {}, \"sim_macs_per_cycle\": {:.4}, \
                 \"table1_peak_macs_per_cycle\": {:.1}, \
                 \"occupancy\": {{{occ}}}, \"bound_unit\": \"{}\", \"bound\": \"{}\", \
                 \"achieved_macs_per_cycle\": {achieved:.4}, \"pct_of_ceiling\": {pct:.4}}}",
                p.index,
                p.step,
                p.dtype,
                p.m,
                p.n,
                p.k,
                p.gemms,
                p.mix.insts,
                p.mix.macs,
                p.mix.loads,
                p.mix.stores,
                p.mix.load_bytes,
                p.mix.store_bytes,
                p.mix.acc_xfers,
                p.sim_cycles,
                p.sim_macs_per_cycle,
                p.table1_peak_macs_per_cycle,
                p.bound_unit,
                p.bound,
            ));
        }
    }
    println!(
        "roofline (per compiled GEMM step: synthesized stream -> CoreSim ceiling vs \
         achieved at {:.0} GHz nominal):\n{}",
        power_mma::runtime::NOMINAL_GHZ,
        roofline_table.render()
    );

    let tune_snapshot = tune_table.snapshot();
    if tune_snapshot.is_empty() {
        eprintln!("autotune: the tune table is empty after seeding compiles");
        return 1;
    }
    // per memoized class: re-run the chosen variant and the dtype's
    // canonical engine on deterministic operands — the identity bit the
    // whole tuner rests on (a variant may only change speed, never bits)
    let mut tuning_rows = Vec::new();
    let mut tuning_identical = true;
    let mut tune_variants = std::collections::BTreeSet::new();
    let mut tune_measured = 0usize;
    let mut tv_scratch = GemmScratch::new();
    let mut tv_bf16_scratch = Bf16Scratch::new();
    let mut tv_i8_scratch = I8Scratch::new();
    for (key, choice) in &tune_snapshot {
        let (tm, tn, tk) = (key.m, key.n, key.k);
        let ta = det_input(tm * tk, 5);
        let tb = det_input(tk * tn, 6);
        let bias = det_input(tn, 9);
        let canon = power_mma::runtime::tune::heuristic_variant(key.dtype);
        let identical = match key.dtype {
            TuneDtype::F32 if key.panel == TunePanel::DftPacked => {
                // DFT classes replay the packed-panel complex dual-GEMM
                // the class actually times — all four GEMMs, the last
                // two with the DftCombine writeback — chosen variant vs
                // canonical, compared bitwise over both output halves
                let tb_im = det_input(tk * tn, 7);
                let xi = det_input(tm * tk, 8);
                let mut run = |re: &mut [f32], im: &mut [f32], s: &mut GemmScratch, v: GemmVariant| {
                    let panels = DftPanels::pack(&tb, &tb_im, tk, tn, v.nr, v.block.kc);
                    let mut t_ii = vec![0f32; tm * tn];
                    let mut t_ir = vec![0f32; tm * tn];
                    gemm_f32_tuned_into(
                        &mut t_ii, &xi, PanelB::Packed(&panels.im), tm, tn, tk,
                        Accum::F64, Epilogue::None, Par::Seq, s, v,
                    );
                    gemm_f32_tuned_into(
                        &mut t_ir, &xi, PanelB::Packed(&panels.re), tm, tn, tk,
                        Accum::F64, Epilogue::None, Par::Seq, s, v,
                    );
                    gemm_f32_tuned_into(
                        re, &ta, PanelB::Packed(&panels.re), tm, tn, tk, Accum::F64,
                        Epilogue::DftCombine { other: &t_ii, sub: true }, Par::Seq, s, v,
                    );
                    gemm_f32_tuned_into(
                        im, &ta, PanelB::Packed(&panels.im), tm, tn, tk, Accum::F64,
                        Epilogue::DftCombine { other: &t_ir, sub: false }, Par::Seq, s, v,
                    );
                };
                let (mut re_c, mut im_c) = (vec![0f32; tm * tn], vec![0f32; tm * tn]);
                let (mut re_d, mut im_d) = (vec![0f32; tm * tn], vec![0f32; tm * tn]);
                run(&mut re_c, &mut im_c, &mut tv_scratch, choice.variant);
                run(&mut re_d, &mut im_d, &mut tv_scratch, canon);
                re_c.iter()
                    .zip(&re_d)
                    .chain(im_c.iter().zip(&im_d))
                    .all(|(x, y)| x.to_bits() == y.to_bits())
            }
            TuneDtype::F32 => {
                // im2col classes replay through the same synthetic gather
                // spec the tuner measures with (identity k-row gather over
                // a k×n image) under the conv execution contract
                // (f32 accumulate); matrix classes replay the dot contract
                let spec = Im2colSpec {
                    bases: (0..tk).map(|p| p * tn).collect(),
                    img_w: tn,
                    out_w: tn,
                };
                let mut run = |c: &mut [f32], s: &mut GemmScratch, v: GemmVariant| {
                    let epi = match key.epi {
                        TuneEpi::None => Epilogue::None,
                        TuneEpi::Bias => Epilogue::Bias(&bias),
                        TuneEpi::BiasRelu => Epilogue::BiasRelu(&bias),
                    };
                    let (src, accum) = match key.panel {
                        TunePanel::Im2col => {
                            (PanelB::Im2col { img: &tb, spec: &spec }, Accum::F32)
                        }
                        _ => (PanelB::Matrix(&tb), Accum::F64),
                    };
                    gemm_f32_tuned_into(
                        c,
                        &ta,
                        src,
                        tm,
                        tn,
                        tk,
                        accum,
                        epi,
                        Par::Seq,
                        s,
                        v,
                    );
                };
                let mut chosen = vec![0f32; tm * tn];
                let mut def = vec![0f32; tm * tn];
                run(&mut chosen, &mut tv_scratch, choice.variant);
                run(&mut def, &mut tv_scratch, canon);
                chosen.iter().zip(&def).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            TuneDtype::Bf16 => {
                let mut run = |c: &mut [f32], s: &mut Bf16Scratch, v: GemmVariant| {
                    let epi = match key.epi {
                        TuneEpi::None => Epilogue::None,
                        TuneEpi::Bias => Epilogue::Bias(&bias),
                        TuneEpi::BiasRelu => Epilogue::BiasRelu(&bias),
                    };
                    gemm_bf16_tuned_into(
                        c,
                        Bf16Src::F32(&ta),
                        Bf16Src::F32(&tb),
                        tm,
                        tn,
                        tk,
                        Bf16Accum::Widened,
                        epi,
                        Par::Seq,
                        s,
                        v,
                    );
                };
                let mut chosen = vec![0f32; tm * tn];
                let mut def = vec![0f32; tm * tn];
                run(&mut chosen, &mut tv_bf16_scratch, choice.variant);
                run(&mut def, &mut tv_bf16_scratch, canon);
                chosen.iter().zip(&def).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            TuneDtype::I8 => {
                let tq =
                    QuantParams { a_scale: 0.02, a_zp: -5, b_scale: 0.017, b_zp: 120 };
                let mut run = |c: &mut [f32], s: &mut I8Scratch, v: GemmVariant| {
                    let epi = match key.epi {
                        TuneEpi::None => I8Epilogue::None,
                        TuneEpi::Bias => I8Epilogue::Bias(&bias),
                        TuneEpi::BiasRelu => I8Epilogue::BiasRelu(&bias),
                    };
                    gemm_i8_dequant_tuned_into(
                        c, &ta, &tb, tm, tn, tk, &tq, epi, Par::Seq, s, v,
                    );
                };
                let mut chosen = vec![0f32; tm * tn];
                let mut def = vec![0f32; tm * tn];
                run(&mut chosen, &mut tv_i8_scratch, choice.variant);
                run(&mut def, &mut tv_i8_scratch, canon);
                chosen.iter().zip(&def).all(|(x, y)| x.to_bits() == y.to_bits())
            }
        };
        tuning_identical &= identical;
        tune_variants.insert(choice.variant.name());
        tune_measured += usize::from(choice.measured);
        println!(
            "tune {:4} {tm:3}x{tn:3}x{tk:3} {:6} epi {:9} -> {:20} \
             ({}, chosen {:.3} ms vs default {:.3} ms) numerics {}",
            key.dtype.as_str(),
            key.panel.as_str(),
            key.epi.as_str(),
            choice.variant.name(),
            if choice.measured { "measured" } else { "heuristic" },
            choice.chosen_ms,
            choice.default_ms,
            if identical { "identical" } else { "DIFFER" }
        );
        tuning_rows.push(format!(
            "{{\"m\": {tm}, \"n\": {tn}, \"k\": {tk}, \"dtype\": \"{}\", \
             \"panel\": \"{}\", \"epilogue\": \"{}\", \"variant\": \"{}\", \
             \"chosen_ms\": {:.4}, \
             \"default_ms\": {:.4}, \"measured\": {}, \"identical\": {identical}}}",
            key.dtype.as_str(),
            key.panel.as_str(),
            key.epi.as_str(),
            choice.variant.name(),
            choice.chosen_ms,
            choice.default_ms,
            choice.measured
        ));
    }
    let tune_distinct = tune_variants.len();
    println!(
        "tune table: {} classes, {tune_measured} measured, {tune_distinct} distinct \
         variants, numerics {}",
        tune_snapshot.len(),
        if tuning_identical { "identical" } else { "DIFFER" }
    );

    // -- 7. coordinator end-to-end over the plan backend, shards 1 vs 2 --
    // this bench drives a single model family (classify), so sticky
    // routing funnels everything through one shard — the round-robin
    // default keeps shards=1-vs-2 a measurement of engine concurrency;
    // CI also runs the whole bench under --routing sticky
    let n_coord = if quick { 400 } else { 4000 };
    let ladder = power_mma::coordinator::CoordinatorConfig::default().ladder();
    let shard_opts = |label: &str, shards: usize| CoordBenchOpts {
        label: label.to_string(),
        n_req: n_coord,
        shards,
        routing,
        buckets: ladder.clone(),
        window: Duration::from_millis(2),
        quiet: false,
    };
    let (coord1, coord2) =
        match (bench_coordinator(shard_opts("s1", 1)), bench_coordinator(shard_opts("s2", 2))) {
            (Ok(c1), Ok(c2)) => (c1, c2),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("coordinator bench failed: {e}");
                return 1;
            }
        };
    let shard_identical = coord1.probe.len() == coord2.probe.len()
        && coord1
            .probe
            .iter()
            .zip(&coord2.probe)
            .all(|(x, y)| x.to_bits() == y.to_bits());
    println!(
        "coordinator shards: 1 -> {:.0} req/s | 2 -> {:.0} req/s | probe numerics {}",
        coord1.req_per_s,
        coord2.req_per_s,
        if shard_identical { "identical" } else { "DIFFER" }
    );

    // -- 8. continuous batching: bucket-ladder + window sweeps, identity -
    // per-bucket: force a singleton ladder [b] so every window executes
    // in (and pads to) exactly that compiled bucket — req/s vs p99 shows
    // the utilization-vs-latency trade of the paper's m dimension
    let n_batch = if quick { 240 } else { 1200 };
    let mut per_bucket_rows = Vec::new();
    for &bkt in &ladder {
        let cb = match bench_coordinator(CoordBenchOpts {
            label: format!("b{bkt}"),
            n_req: n_batch,
            shards: 1,
            routing,
            buckets: vec![bkt],
            window: Duration::from_millis(2),
            quiet: true,
        }) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("batching bucket {bkt} bench failed: {e}");
                return 1;
            }
        };
        println!(
            "batching bucket {bkt:3}: {:7.0} req/s, p50 {:5} us, p99 {:5} us, occupancy {:.2}",
            cb.req_per_s,
            cb.p50_us,
            cb.p99_us,
            cb.stats.mean_batch_occupancy()
        );
        per_bucket_rows.push(format!(
            "{{\"bucket\": {bkt}, \"req_per_s\": {:.1}, \"p50_us\": {}, \"p99_us\": {}, \
             \"occupancy\": {:.3}}}",
            cb.req_per_s,
            cb.p50_us,
            cb.p99_us,
            cb.stats.mean_batch_occupancy()
        ));
    }
    // window sweep: the full ladder under three deadlines — the
    // per-bucket flush counters show where the continuous batcher
    // actually lands each window
    let mut window_rows = Vec::new();
    for &wus in &[500u64, 2000, 8000] {
        let cb = match bench_coordinator(CoordBenchOpts {
            label: format!("w{wus}"),
            n_req: n_batch,
            shards: 1,
            routing,
            buckets: ladder.clone(),
            window: Duration::from_micros(wus),
            quiet: true,
        }) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("batching window {wus}us bench failed: {e}");
                return 1;
            }
        };
        let bucket_cells: Vec<String> = cb
            .stats
            .buckets
            .iter()
            .map(|s| {
                format!(
                    "{{\"bucket\": {}, \"flushes_full\": {}, \"flushes_deadline\": {}, \
                     \"flushes_shutdown\": {}, \"rows\": {}, \"occupancy\": {:.3}}}",
                    s.bucket,
                    s.full.get(),
                    s.deadline.get(),
                    s.shutdown.get(),
                    s.rows.get(),
                    s.occupancy()
                )
            })
            .collect();
        println!(
            "batching window {wus:5} us: {:7.0} req/s, p50 {:5} us, p99 {:5} us, \
             occupancy {:.2}",
            cb.req_per_s,
            cb.p50_us,
            cb.p99_us,
            cb.stats.mean_batch_occupancy()
        );
        window_rows.push(format!(
            "{{\"window_us\": {wus}, \"req_per_s\": {:.1}, \"p50_us\": {}, \"p99_us\": {}, \
             \"occupancy\": {:.3}, \"buckets\": [{}]}}",
            cb.req_per_s,
            cb.p50_us,
            cb.p99_us,
            cb.stats.mean_batch_occupancy(),
            bucket_cells.join(", ")
        ));
    }
    let batch_identical = match batching_identity_check(routing) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("batched-vs-singleton identity check failed to run: {e}");
            return 1;
        }
    };
    println!(
        "batching identity: batched (ladder {ladder:?}) vs singleton responses {}",
        if batch_identical { "identical" } else { "DIFFER" }
    );

    // -- 8b. DFT: the second served model family end to end --------------
    // the missing-fixture failure mode degrades to a diagnostic + nonzero
    // exit, never a panic (ci/check_bench.py then fails loudly on the
    // absent `dft` block)
    let Some(dft_art) = artifacts::EMBEDDED.iter().find(|a| a.name == "dft_b32") else {
        eprintln!("dft_b32 fixture missing from the embedded artifact set");
        return 1;
    };
    let dft_meta_parsed = match ModelMeta::parse(dft_art.meta) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("dft_b32: bad meta: {e}");
            return 1;
        }
    };
    // plan shape first: the lowered twiddle-multiply structure (four real
    // dots plus the ± combines) must collapse to exactly one fused
    // dft_gemm step over once-packed Fourier panels, no raw dots left
    let dft_plan = match power_mma::runtime::hlo::HloModule::parse(dft_art.hlo_text)
        .and_then(|m| power_mma::runtime::plan::Plan::compile(&m))
    {
        Ok(p) => p,
        Err(e) => {
            eprintln!("dft_b32: plan compile failed: {e}");
            return 1;
        }
    };
    let dft_step_names = dft_plan.step_names();
    let dft_gemm_steps = dft_step_names.iter().filter(|&&s| s == "dft_gemm").count();
    let dft_plan_fused =
        dft_gemm_steps == 1 && !dft_step_names.iter().any(|&s| s == "dot");
    println!(
        "dft_b32 plan: {} steps {dft_step_names:?} ({})",
        dft_plan.num_steps(),
        if dft_plan_fused { "four dots fused into one dft_gemm" } else { "NOT fused" }
    );
    if !dft_plan_fused {
        eprintln!(
            "dft_b32 must compile to a plan with exactly one dft_gemm step and no \
             raw dot steps (got {dft_step_names:?})"
        );
        return 1;
    }
    // the rust bucket generator must reproduce the JAX-lowered fixture
    // byte for byte — the cross-language contract `serve`'s ladder rests on
    if dft_hlo_text(32) != dft_art.hlo_text {
        eprintln!("dft_hlo_text(32) does not reproduce the dft_b32 AOT fixture");
        return 1;
    }
    // numeric identity: fused plan vs interpreter vs the twiddle-table
    // oracle, all bitwise; plus tolerance cross-checks against the
    // fixture bytes (JAX's own f32 dot output) and the libm f64 scalar
    // DFT
    let dft_backends = (
        HloInterpreterBackend.compile(
            &shared_dev,
            dft_art.name,
            dft_art.hlo_text,
            &dft_meta_parsed,
        ),
        HloPlanBackend::new().compile(
            &shared_dev,
            dft_art.name,
            dft_art.hlo_text,
            &dft_meta_parsed,
        ),
    );
    let (dft_interp, dft_fused) = match dft_backends {
        (Ok(i), Ok(p)) => (i, p),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("dft_b32: compile failed: {e}");
            return 1;
        }
    };
    let dft_inputs = det_inputs(&dft_meta_parsed);
    let (dft_iout, dft_pout) = {
        let mut ctx = shared_dev.ctx();
        (
            run_model(dft_interp.as_ref(), &mut ctx, &dft_meta_parsed, &dft_inputs),
            run_model(dft_fused.as_ref(), &mut ctx, &dft_meta_parsed, &dft_inputs),
        )
    };
    let dft_batch = dft_meta_parsed.input_shapes[0][0];
    let dft_want = dft_oracle(&dft_inputs[0], &dft_inputs[1], dft_batch, 16);
    let dft_fixture: Vec<f32> = dft_art
        .expected
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let dft_identical = dft_pout.len() == dft_iout.len()
        && dft_pout.len() == dft_want.len()
        && dft_pout.iter().zip(&dft_iout).all(|(x, y)| x.to_bits() == y.to_bits())
        && dft_pout.iter().zip(&dft_want).all(|(x, y)| x.to_bits() == y.to_bits());
    let mut dft_fixture_err = 0f64;
    for (x, y) in dft_pout.iter().zip(&dft_fixture) {
        dft_fixture_err = dft_fixture_err.max((f64::from(*x) - f64::from(*y)).abs());
    }
    let dft_fixture_close = dft_pout.len() == dft_fixture.len() && dft_fixture_err < 1e-4;
    // dft_reference is sample-major (one transform per column) in f64
    // with libm twiddles — transpose in, compare within f32 rounding
    let (ref_xr, ref_xi) = {
        let n = 16usize;
        let mut xr = vec![0f64; n * dft_batch];
        let mut xi = vec![0f64; n * dft_batch];
        for r in 0..dft_batch {
            for k in 0..n {
                xr[k * dft_batch + r] = dft_inputs[0][r * n + k] as f64;
                xi[k * dft_batch + r] = dft_inputs[1][r * n + k] as f64;
            }
        }
        (xr, xi)
    };
    let (ref_yr, ref_yi) = dft_reference(&ref_xr, &ref_xi, 16, dft_batch);
    let mut dft_ref_err = 0f64;
    for r in 0..dft_batch {
        for j in 0..16 {
            let er = (dft_pout[r * 16 + j] as f64 - ref_yr[j * dft_batch + r]).abs();
            let ei = (dft_pout[(dft_batch + r) * 16 + j] as f64
                - ref_yi[j * dft_batch + r])
                .abs();
            dft_ref_err = dft_ref_err.max(er).max(ei);
        }
    }
    let dft_ref_close = dft_ref_err < 1e-4;
    // served two-family traffic: mixed classify + DFT through one
    // coordinator, every DFT response checked bitwise against its oracle
    let n_mix = if quick { 400 } else { 4000 };
    let dft_mix = match dft_mix_bench(n_mix, routing) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("dft two-family coordinator bench failed: {e}");
            return 1;
        }
    };
    let dft_numerics = dft_identical && dft_fixture_close && dft_ref_close && dft_mix.rows_exact;
    println!(
        "dft_b32 fused vs interpreter/oracle {} | vs JAX fixture max |err| \
         {dft_fixture_err:.2e} | vs f64 reference max |err| {dft_ref_err:.2e} | \
         sim MACs/cycle f32 {:.2}",
        if dft_identical { "identical" } else { "DIFFER" },
        fpc_f32_4x / 2.0
    );
    println!(
        "dft mix ({} classify + {} dft): {:.0} req/s, p50 {} us, p99 {} us, rows {} | \
         throttled mlp {} dft {}",
        dft_mix.classify_requests,
        dft_mix.dft_requests,
        dft_mix.req_per_s,
        dft_mix.p50_us,
        dft_mix.p99_us,
        if dft_mix.rows_exact { "identical" } else { "DIFFER" },
        dft_mix.mlp_throttled,
        dft_mix.dft_throttled
    );
    let dft_json = format!(
        "{{\"plan_steps\": {}, \"dft_gemm_steps\": {dft_gemm_steps}, \
         \"generated_matches_fixture\": true, \"identical\": {dft_identical}, \
         \"max_abs_err_vs_fixture\": {dft_fixture_err:.3e}, \
         \"max_abs_err_vs_f64_reference\": {dft_ref_err:.3e}, \
         \"sim_macs_per_cycle_f32\": {:.3}, \
         \"mix\": {{\"requests\": {n_mix}, \"classify_requests\": {}, \
         \"dft_requests\": {}, \"req_per_s\": {:.1}, \"p50_us\": {}, \"p99_us\": {}, \
         \"rows_identical\": {}, \"throttled\": {{\"mlp\": {}, \"dft\": {}}}, \
         \"dft_buckets\": [{}]}}}}",
        dft_plan.num_steps(),
        fpc_f32_4x / 2.0,
        dft_mix.classify_requests,
        dft_mix.dft_requests,
        dft_mix.req_per_s,
        dft_mix.p50_us,
        dft_mix.p99_us,
        dft_mix.rows_exact,
        dft_mix.mlp_throttled,
        dft_mix.dft_throttled,
        dft_mix.dft_bucket_cells.join(", ")
    );

    let numerics_ok = all_identical
        && pool_gemm_identical
        && shard_identical
        && bf16_identical
        && bf16_pairs_identical
        && plan_pairs_identical
        && int8_identical
        && batch_identical
        && tuning_identical
        && dft_numerics;

    // -- 9. machine-readable report --------------------------------------
    let json = format!(
        "{{\n  \"bench\": \"runtime\",\n  \"quick\": {quick},\n  \"size\": {size},\n  \
         \"threads_available\": {avail},\n  \"threads_swept\": {threads:?},\n  \
         \"routing\": \"{routing_name}\",\n  \
         \"gemm\": [\n    {}\n  ],\n  \
         \"plan_vs_interpreter\": {{\"size\": {size}, \"interpreter_ms\": {interp_ms:.3}, \
         \"plan\": [\n    {}\n  ], \"speedup_best\": {speedup:.3}}},\n  \
         \"fixtures\": [\n    {}\n  ],\n  \
         \"conv\": {{\"plan_steps\": {conv_steps}, \"im2col_gemm_steps\": {conv_gemms}, \
         \"max_steps\": 10}},\n  \
         \"bf16\": {{\"size\": {size}, \"plan_has_dot_bf16\": {plan_has_dot_bf16}, \
         \"widened_ms\": {bf16_widened_ms:.3}, \"packed_ms\": {bf16_packed_ms:.3}, \
         \"packed_vs_widened\": {:.3}, \"identical\": {bf16_identical}, \
         \"f32pairs_ms\": {bf16_pairs_ms:.3}, \
         \"f32pairs_identical\": {bf16_pairs_identical}, \
         \"plan_f32pairs_identical\": {plan_pairs_identical}, \
         \"sim_macs_per_cycle_f32\": {:.3}, \"sim_macs_per_cycle_bf16\": {:.3}, \
         \"sim_macs_per_cycle_ratio\": {macs_ratio:.3}}},\n  \
         \"int8\": {{\"size\": {size}, \"plan_has_dot_i8\": {plan_has_dot_i8}, \
         \"f32_ms\": {pool_ms:.3}, \"packed_ms\": {int8_ms:.3}, \
         \"packed_vs_f32\": {:.3}, \"identical\": {int8_identical}, \
         \"max_abs_err_vs_f32\": {int8_max_err:.6}, \
         \"sim_macs_per_cycle_f32\": {:.3}, \"sim_macs_per_cycle_i8\": {:.3}, \
         \"sim_macs_per_cycle_ratio\": {int8_macs_ratio:.3}}},\n  \
         \"pool\": {{\"gemm_scoped_ms\": {scoped_ms:.3}, \"gemm_pool_ms\": {pool_ms:.3}, \
         \"gemm_identical\": {pool_gemm_identical}, \
         \"shards1_req_per_s\": {:.1}, \"shards2_req_per_s\": {:.1}, \
         \"shard_numerics_identical\": {shard_identical}}},\n  \
         \"coordinator\": {},\n  \
         \"coordinator_sharded\": {},\n  \
         \"batching\": {{\"ladder\": {ladder:?}, \"routing\": \"{routing_name}\", \
         \"requests_per_run\": {n_batch}, \
         \"per_bucket\": [\n    {}\n  ], \
         \"windows\": [\n    {}\n  ], \
         \"batched_vs_singleton_identical\": {batch_identical}}},\n  \
         \"tuning\": {{\"enabled\": true, \"classes\": {}, \
         \"measured_classes\": {tune_measured}, \"distinct_variants\": {tune_distinct}, \
         \"identical\": {tuning_identical}, \
         \"table\": [\n    {}\n  ]}},\n  \
         \"dft\": {dft_json},\n  \
         \"roofline\": {{\"nominal_ghz\": {:.1}, \"pct_in_range\": {roofline_in_range}, \
         \"steps\": [\n    {}\n  ]}},\n  \
         \"acceptance\": {{\"target_speedup\": 3.0, \"achieved\": {speedup:.3}, \
         \"pass\": {}, \"numerics_identical\": {numerics_ok}}}\n}}\n",
        gemm_rows.join(",\n    "),
        plan_rows.join(",\n    "),
        fixture_rows.join(",\n    "),
        bf16_widened_ms / bf16_packed_ms,
        fpc_f32 / 2.0,
        fpc_bf16 / 2.0,
        pool_ms / int8_ms,
        fpc_f32_4x / 2.0,
        fpc_i8 / 2.0,
        coord1.req_per_s,
        coord2.req_per_s,
        coord1.json,
        coord2.json,
        per_bucket_rows.join(",\n    "),
        window_rows.join(",\n    "),
        tune_snapshot.len(),
        tuning_rows.join(",\n    "),
        power_mma::runtime::NOMINAL_GHZ,
        roofline_rows.join(",\n    "),
        speedup >= 3.0
    );
    let out_path = m.get("out");
    if let Err(e) = std::fs::write(out_path, &json) {
        eprintln!("cannot write {out_path}: {e}");
        return 1;
    }
    println!(
        "\nplan-vs-interpreter best speedup: {speedup:.2}x (numerics identical: {numerics_ok})\nwrote {out_path}"
    );
    if numerics_ok {
        0
    } else {
        1
    }
}

fn cmd_gen_artifacts(args: &[String]) -> i32 {
    use power_mma::runtime::artifacts;
    let cmd = Command::new(
        "power-mma gen-artifacts",
        "write the embedded AOT artifact set (HLO text + meta + expected outputs) to disk",
    )
    .opt("out", Some("artifacts"), "output directory");
    let m = parse_or_exit(cmd, args);
    let dir = std::path::PathBuf::from(m.get("out"));
    match artifacts::write_artifacts(&dir) {
        Ok(()) => {
            for a in artifacts::EMBEDDED {
                println!("  {}: {} chars of HLO text", a.name, a.hlo_text.len());
            }
            println!("wrote {} artifacts + manifest to {}", artifacts::EMBEDDED.len(), dir.display());
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
