#!/usr/bin/env python3
"""Assert the invariants of a ``BENCH_runtime.json`` report.

The single gate shared by CI (``.github/workflows/ci.yml``) and local
runs::

    cargo run --release -- bench serve --quick --out BENCH_runtime.json
    python3 ci/check_bench.py BENCH_runtime.json

Checks are *correctness* invariants, never absolute performance numbers
(CI runners are noisy): plan shapes, bitwise-identity bits, block
presence, and req/s strictly positive. Exits non-zero with a pointed
message on the first violation.
"""

import json
import sys


def fail(msg):
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def need(report, key):
    if key not in report:
        fail(f"required block '{key}' missing from report")
    return report[key]


def check(report):
    # -- acceptance: every bitwise-identity bit folded together --------
    acceptance = need(report, "acceptance")
    if acceptance.get("numerics_identical") is not True:
        fail(f"acceptance.numerics_identical is not true: {acceptance}")

    # -- plan shapes ---------------------------------------------------
    conv = need(report, "conv")
    if not conv.get("plan_steps", 10**9) <= 10:
        fail(f"conv fixture must compile to <= 10 plan steps: {conv}")
    if conv.get("im2col_gemm_steps") != 1:
        fail(f"conv fixture must fuse to exactly one im2col GEMM: {conv}")

    # -- bf16 engine: packed plan step + both accumulation contracts ---
    bf16 = need(report, "bf16")
    if bf16.get("plan_has_dot_bf16") is not True:
        fail(f"gemm_bf16 plan lost its packed dot_bf16 step: {bf16}")
    if bf16.get("identical") is not True:
        fail(f"bf16 packed path is not bitwise identical to widened: {bf16}")
    if not bf16.get("packed_vs_widened", 0) > 0:
        fail(f"bf16 packed-vs-widened ratio must be positive: {bf16}")
    if bf16.get("f32pairs_identical") is not True:
        fail(f"bf16 F32Pairs path diverges from its pairs oracle: {bf16}")
    if bf16.get("plan_f32pairs_identical") is not True:
        fail(f"F32Pairs-compiled plan diverges from the pairs oracle: {bf16}")

    # -- int8 engine: quantized plan step, Machine parity, Table I 4x --
    int8 = need(report, "int8")
    if int8.get("plan_has_dot_i8") is not True:
        fail(f"calibrated MLP plan lost its quantized dot_i8 step: {int8}")
    if int8.get("identical") is not True:
        fail(f"int8 packed path broke Machine parity or its dequant reference: {int8}")
    if not int8.get("packed_vs_f32", 0) > 0:
        fail(f"int8 packed-vs-f32 ratio must be positive: {int8}")
    if not int8.get("max_abs_err_vs_f32", -1) >= 0:
        fail(f"int8 accuracy-vs-f32 error must be reported: {int8}")
    # Table I ordering: one xvi8ger4 retires 4x the MACs of xvf32ger,
    # rank-2 bf16 only 2x — the sim must rank the integer engine above
    # the bf16 engine at equal MACs
    if not int8.get("sim_macs_per_cycle_ratio", 0) > bf16.get("sim_macs_per_cycle_ratio", 10**9):
        fail(
            "xvi8ger4 sim MACs/cycle ratio must beat the bf16 ratio: "
            f"i8 {int8.get('sim_macs_per_cycle_ratio')} vs "
            f"bf16 {bf16.get('sim_macs_per_cycle_ratio')}"
        )

    # -- coordinator end-to-end ----------------------------------------
    coord = need(report, "coordinator")
    if not coord.get("req_per_s", 0) > 0:
        fail(f"coordinator served no requests: {coord}")
    sharded = need(report, "coordinator_sharded")
    if sharded.get("shards") != 2:
        fail(f"sharded coordinator bench must run with 2 shards: {sharded}")

    # -- pool: persistent-pool GEMM + shard numerics -------------------
    pool = need(report, "pool")
    if pool.get("gemm_identical") is not True:
        fail(f"persistent-pool GEMM diverged from scoped-spawn: {pool}")
    if pool.get("shard_numerics_identical") is not True:
        fail(f"sharded serving diverged from single-shard: {pool}")

    # -- continuous batching -------------------------------------------
    batching = need(report, "batching")
    ladder = batching.get("ladder")
    if not isinstance(ladder, list) or len(ladder) < 3:
        fail(f"batching ladder must list >= 3 bucket sizes: {batching}")
    if ladder != sorted(ladder) or len(set(ladder)) != len(ladder):
        fail(f"batching ladder must be ascending and deduplicated: {ladder}")
    per_bucket = batching.get("per_bucket")
    if not isinstance(per_bucket, list) or len(per_bucket) < 3:
        fail(f"batching.per_bucket must sweep >= 3 bucket sizes: {batching}")
    if [row.get("bucket") for row in per_bucket] != ladder:
        fail(f"per_bucket sweep must cover the ladder {ladder}: {per_bucket}")
    for row in per_bucket:
        if not row.get("req_per_s", 0) > 0:
            fail(f"bucket {row.get('bucket')} served no requests: {row}")
        if not row.get("p99_us", 0) > 0:
            fail(f"bucket {row.get('bucket')} reported no p99 latency: {row}")
    windows = batching.get("windows")
    if not isinstance(windows, list) or len(windows) < 2:
        fail(f"batching.windows must sweep >= 2 window sizes: {batching}")
    for row in windows:
        if not row.get("req_per_s", 0) > 0:
            fail(f"window {row.get('window_us')}us served no requests: {row}")
        flushes = sum(
            b.get("flushes_full", 0)
            + b.get("flushes_deadline", 0)
            + b.get("flushes_shutdown", 0)
            for b in row.get("buckets", [])
        )
        if not flushes > 0:
            fail(f"window {row.get('window_us')}us recorded no bucket flushes: {row}")
    if batching.get("batched_vs_singleton_identical") is not True:
        fail(
            "batched responses are not bitwise identical to singleton "
            f"responses: {batching}"
        )

    # -- DFT: the second served model family ---------------------------
    dft = need(report, "dft")
    if dft.get("dft_gemm_steps") != 1:
        fail(f"the DFT fixture must fuse to exactly one dft_gemm step: {dft}")
    if dft.get("generated_matches_fixture") is not True:
        fail(f"dft_hlo_text must reproduce the AOT fixture byte for byte: {dft}")
    if dft.get("identical") is not True:
        fail(f"fused DFT diverged from interpreter/oracle bits: {dft}")
    if not dft.get("max_abs_err_vs_fixture", -1) >= 0:
        fail(f"DFT accuracy vs the JAX fixture bytes must be reported: {dft}")
    if not dft.get("max_abs_err_vs_f64_reference", -1) >= 0:
        fail(f"DFT accuracy vs the f64 reference must be reported: {dft}")
    mix = need(dft, "mix")
    if not mix.get("req_per_s", 0) > 0:
        fail(f"two-family mix served no requests: {mix}")
    if not mix.get("dft_requests", 0) > 0 or not mix.get("classify_requests", 0) > 0:
        fail(f"the mix must carry traffic from both families: {mix}")
    if mix.get("rows_identical") is not True:
        fail(f"a served DFT response diverged from its per-request oracle: {mix}")
    throttled = need(mix, "throttled")
    for family in ("mlp", "dft"):
        if not throttled.get(family, -1) >= 0:
            fail(f"per-family throttle counter '{family}' missing: {mix}")
    dft_buckets = mix.get("dft_buckets")
    if not isinstance(dft_buckets, list) or not dft_buckets:
        fail(f"the mix must report per-bucket DFT flush counters: {mix}")
    dft_flushes = sum(
        b.get("flushes_full", 0)
        + b.get("flushes_deadline", 0)
        + b.get("flushes_shutdown", 0)
        for b in dft_buckets
    )
    if not dft_flushes > 0:
        fail(f"the mix recorded no DFT bucket flushes: {mix}")
    dft_rows = sum(b.get("rows", 0) for b in dft_buckets)
    if dft_rows != mix.get("dft_requests"):
        fail(
            f"DFT bucket rows {dft_rows} != submitted DFT requests "
            f"{mix.get('dft_requests')}: {mix}"
        )

    # -- autotuner: memoized table, identity per class, audit trail ----
    tuning = need(report, "tuning")
    if tuning.get("enabled") is not True:
        fail(f"the bench must run with the autotuner enabled: {tuning}")
    table = tuning.get("table")
    if not isinstance(table, list) or not table:
        fail(f"tuning.table must be a non-empty list of memoized classes: {tuning}")
    if tuning.get("identical") is not True:
        fail(f"a tuned variant changed bits vs the canonical engine: {tuning}")
    for row in table:
        if row.get("identical") is not True:
            fail(f"tuned class is not bitwise identical to canonical: {row}")
        for key in ("m", "n", "k", "dtype", "epilogue", "variant"):
            if key not in row:
                fail(f"tuning table row missing '{key}': {row}")
        chosen, default = row.get("chosen_ms", -1), row.get("default_ms", -1)
        if chosen < 0 or default < 0:
            fail(f"tuning table row must report chosen/default ms: {row}")
        # chosen_ms <= default_ms holds by construction (canonical-first
        # argmin); 5% tolerance guards against float printing jitter
        if row.get("measured") and chosen > default * 1.05:
            fail(f"chosen variant measured slower than the default: {row}")
    if not tuning.get("distinct_variants", 0) >= 2:
        fail(
            "the tuner must pick >= 2 distinct variants across classes "
            f"(a single winner means the search is vacuous): {tuning}"
        )
    if not tuning.get("measured_classes", 0) >= 1:
        fail(f"at least one class must be measured (not heuristic): {tuning}")

    # -- roofline: per-step observability over every served family -----
    roofline = need(report, "roofline")
    if not roofline.get("nominal_ghz", 0) > 0:
        fail(f"roofline must report the nominal clock: {roofline}")
    steps = roofline.get("steps")
    if not isinstance(steps, list) or not steps:
        fail(f"roofline.steps must be a non-empty list: {roofline}")
    families = {row.get("family") for row in steps}
    for family in ("mlp_f32", "gemm_bf16", "mlp_int8", "dft_b32"):
        if family not in families:
            fail(f"roofline is missing served family '{family}': {sorted(families)}")
    if roofline.get("pct_in_range") is not True:
        fail(f"roofline.pct_in_range must be true: {roofline.get('pct_in_range')}")
    best_ceiling = {}
    for row in steps:
        where = f"{row.get('family')}/{row.get('step')}"
        for key in ("dtype", "m", "n", "k", "variant", "gemms", "sim_cycles", "bound"):
            if key not in row:
                fail(f"roofline step {where} missing '{key}': {row}")
        mix = row.get("mix")
        if not isinstance(mix, dict):
            fail(f"roofline step {where} missing its instruction mix: {row}")
        macs = mix.get("macs", 0)
        expect = row.get("gemms", 0) * row.get("m", 0) * row.get("n", 0) * row.get("k", 0)
        if macs != expect:
            fail(f"roofline step {where} mix.macs {macs} != gemms*m*n*k {expect}")
        if not mix.get("insts", 0) > 0 or not isinstance(mix.get("opcodes"), dict):
            fail(f"roofline step {where} mix must carry insts and opcodes: {mix}")
        ceiling = row.get("sim_macs_per_cycle", 0)
        peak = row.get("table1_peak_macs_per_cycle", 0)
        if not 0 < ceiling <= peak * 1.0001:
            fail(f"roofline step {where} ceiling {ceiling} outside (0, peak {peak}]")
        pct = row.get("pct_of_ceiling", -1)
        if not 0 < pct <= 1.05:
            fail(f"roofline step {where} pct_of_ceiling {pct} outside (0, 1.05]")
        if not row.get("achieved_macs_per_cycle", 0) > 0:
            fail(f"roofline step {where} reported no achieved MACs/cycle: {row}")
        dtype = row.get("dtype")
        best_ceiling[dtype] = max(best_ceiling.get(dtype, 0), ceiling)
    for dtype in ("f32", "bf16", "i8"):
        if dtype not in best_ceiling:
            fail(f"roofline covers no '{dtype}' step: {sorted(best_ceiling)}")
    # Table I ordering over the simulated ceilings: the rank-4 integer
    # engine must out-rank rank-2 bf16, which must out-rank rank-1 f32
    if not best_ceiling["i8"] >= best_ceiling["bf16"] >= best_ceiling["f32"]:
        fail(f"roofline ceilings violate Table-I ordering i8>=bf16>=f32: {best_ceiling}")

    print(
        "check_bench: OK:"
        f" speedup {acceptance.get('achieved')},"
        f" conv steps {conv.get('plan_steps')},"
        f" bf16 packed-vs-widened {bf16.get('packed_vs_widened')},"
        f" int8 packed-vs-f32 {int8.get('packed_vs_f32')}"
        f" (sim ratio {int8.get('sim_macs_per_cycle_ratio')}),"
        f" coord req/s {coord.get('req_per_s')},"
        f" sharded req/s {sharded.get('req_per_s')},"
        f" ladder {ladder},"
        f" bucket req/s {[row.get('req_per_s') for row in per_bucket]},"
        f" batched==singleton {batching.get('batched_vs_singleton_identical')},"
        f" dft mix req/s {mix.get('req_per_s')}"
        f" (rows identical {mix.get('rows_identical')}),"
        f" tuned classes {len(table)}"
        f" ({tuning.get('distinct_variants')} variants,"
        f" {tuning.get('measured_classes')} measured),"
        f" roofline steps {len(steps)}"
        f" (ceilings {[f'{d}:{best_ceiling[d]:.1f}' for d in ('f32', 'bf16', 'i8')]})"
    )


def main(argv):
    paths = argv[1:] or ["BENCH_runtime.json"]
    for path in paths:
        try:
            with open(path) as f:
                report = json.load(f)
        except OSError as e:
            fail(f"cannot read {path}: {e}")
        except json.JSONDecodeError as e:
            fail(f"{path} is not valid JSON: {e}")
        print(f"check_bench: {path}")
        check(report)


if __name__ == "__main__":
    main(sys.argv)
