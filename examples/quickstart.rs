//! Quickstart: a five-minute tour of the `power-mma` stack.
//!
//! 1. write an MMA kernel with the builtins API (paper §IV);
//! 2. run it bit-exactly on the functional ISA simulator (§II);
//! 3. inspect its binary encoding (the Figure 7 object-code view);
//! 4. time it on the POWER10 cycle model (§III);
//! 5. compare with the POWER9 vector baseline (§VI).
//!
//! Run: `cargo run --release --example quickstart`

use power_mma::builtins::{Gpr, KernelBuilder};
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::isa::asm::disassemble_program;
use power_mma::isa::encode::encode_program;
use power_mma::isa::inst::{AccOp, GerKind};
use power_mma::isa::Machine;
use power_mma::kernels::vsx::vsx_dgemm_8x4_program;

fn main() -> power_mma::error::Result<()> {
    // ---- 1. a tiny kernel via builtins: C(4x4) = sum_k x_k y_k^T --------
    let mut b = KernelBuilder::new();
    let acc = b.alloc_acc()?;
    let x = b.alloc_vec()?;
    let y = b.alloc_vec()?;
    let (px, py, pc, n) = (Gpr(4), Gpr(5), Gpr(3), Gpr(9));
    b.li(n, 8);
    b.mtctr(n);
    b.xxsetaccz(acc); // __builtin_mma_xxsetaccz: prime the accumulator
    let top = b.label();
    b.lxv(x, px, 0); // stream one fp32x4 column of X
    b.lxv(y, py, 0); // ... and one row of Y^T
    b.ger(GerKind::F32Ger, AccOp::PP, acc, x, y)?; // __builtin_mma_xvf32gerpp
    b.addi(px, px, 16);
    b.addi(py, py, 16);
    b.bdnz(top);
    b.store_acc(acc, pc, 0)?; // __builtin_mma_disassemble_acc + stores
    let prog = b.finish();

    println!("== generated kernel ({} instructions) ==", prog.len());
    print!("{}", disassemble_program(&prog));

    // ---- 2. run it on the functional machine ---------------------------
    let mut m = Machine::new(4096);
    let xs: Vec<f32> = (0..32).map(|i| (i % 5) as f32).collect();
    let ys: Vec<f32> = (0..32).map(|i| (i % 3) as f32 - 1.0).collect();
    m.write_f32s(0, &xs);
    m.write_f32s(512, &ys);
    m.gpr[4] = 0;
    m.gpr[5] = 512;
    m.gpr[3] = 1024;
    m.run(&prog, 10_000)?;
    let c = m.read_f32s(1024, 16);
    println!("\n== functional result (4x4 accumulator) ==");
    for row in c.chunks(4) {
        println!("  {row:?}");
    }
    // check one element against scalar math
    let c00: f32 = (0..8).map(|k| xs[4 * k] * ys[4 * k]).sum();
    assert_eq!(c[0], c00);

    // ---- 3. binary encoding --------------------------------------------
    let bytes = encode_program(&prog)?;
    println!("\n== first 4 encoded words (Power ISA v3.1) ==");
    for w in bytes.chunks_exact(4).take(4) {
        println!("  {:08x}", u32::from_le_bytes(w.try_into().unwrap()));
    }

    // ---- 4. time it on the POWER10 model --------------------------------
    let mut sim = CoreSim::new(MachineConfig::power10());
    sim.gpr[4] = 0;
    sim.gpr[5] = 512;
    sim.gpr[3] = 1024;
    let r = sim.run(&prog, 10_000);
    println!(
        "\n== POWER10 timing == {} cycles for {} instructions ({:.2} flops/cycle)",
        r.cycles,
        r.instructions,
        r.flops_per_cycle()
    );

    // ---- 5. the POWER9 vector baseline ----------------------------------
    let mut p9 = CoreSim::new(MachineConfig::power9());
    let rv = p9.run(&vsx_dgemm_8x4_program(128), 1 << 22);
    let mut p10 = CoreSim::new(MachineConfig::power10());
    let rm = p10.run(&power_mma::kernels::dgemm::dgemm_8xnx8_program(128), 1 << 22);
    println!(
        "\n== paper §VI headline == POWER9 vector {:.2} vs POWER10 MMA {:.2} flops/cycle ({:.1}x)",
        rv.flops_per_cycle(),
        rm.flops_per_cycle(),
        rm.flops_per_cycle() / rv.flops_per_cycle()
    );
    Ok(())
}
