//! **End-to-end driver**: the paper's §VI evaluation on a real (small)
//! workload, proving all layers compose.
//!
//! Phase 1 — *functional*: solve a dense 192×192 system with HPL where
//! every trailing-update MAC executes as simulated `xvf64gerpp`
//! instructions through the builtins-generated Figure 6 kernel, then check
//! the HPL residual.
//!
//! Phase 2 — *timing*: regenerate the Figure 10 sweep (POWER9 /
//! POWER10-VSX / POWER10-MMA) from the same LU work profile against the
//! cycle model, reporting flops/cycle and the paper's headline 4× claim.
//!
//! Run: `cargo run --release --example hpl_end_to_end`
//! (results recorded in EXPERIMENTS.md)

use power_mma::benchkit::f2;
use power_mma::blas::gemm::SimMmaGemm;
use power_mma::hpl::{hpl_cycles, hpl_run, CycleCost, Setup};
use power_mma::metrics::Table;

fn main() -> power_mma::error::Result<()> {
    // ---- phase 1: functional HPL over the instruction-level simulator ---
    let n = 192;
    let nb = 64;
    println!("phase 1: functional HPL N={n} NB={nb} on the simulated MMA datapath");
    let t0 = std::time::Instant::now();
    let mut backend = SimMmaGemm::default();
    let r = hpl_run(n, nb, 42, &mut backend)?;
    println!(
        "  residual {:.3e} -> {} ({:.2?})",
        r.residual,
        if r.passed() { "PASSED" } else { "FAILED" },
        t0.elapsed()
    );
    println!(
        "  {} dynamic instructions, {} rank-2 updates, {} flops through the simulated MME",
        backend.stats.instructions, backend.stats.mma_instructions, backend.stats.flops
    );
    assert!(r.passed(), "HPL residual check failed");
    assert_eq!(
        backend.stats.flops,
        r.profile.gemm_flops,
        "every trailing-update MAC must flow through MMA instructions"
    );

    // ---- phase 2: the Figure 10 sweep ------------------------------------
    println!("\nphase 2: Figure 10 sweep (trace-driven cycle model)");
    let sizes = [512usize, 1024, 2048, 4096, 8192];
    let mut table = Table::new(&["N", "POWER9", "POWER10-VSX", "POWER10-MMA", "MMA/P9"]);
    let mut costs: Vec<CycleCost> = Setup::ALL.iter().map(|&s| CycleCost::new(s)).collect();
    let mut final_ratio = 0.0;
    for &size in &sizes {
        let mut vals = Vec::new();
        for (i, &setup) in Setup::ALL.iter().enumerate() {
            vals.push(hpl_cycles(setup, size, 128, &mut costs[i]).flops_per_cycle());
        }
        final_ratio = vals[2] / vals[0];
        table.row(&[
            size.to_string(),
            f2(vals[0]),
            f2(vals[1]),
            f2(vals[2]),
            f2(final_ratio),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper §VI: \"Performance per core is 4 times better, at constant frequency, than \
         the previous generation POWER9\" — measured at N=8192: {final_ratio:.2}x"
    );
    assert!(final_ratio > 3.0, "the headline 4x gain must reproduce (got {final_ratio:.2})");
    Ok(())
}
