//! **Serving end-to-end driver** — the §I "data-in-flight business
//! analytics" scenario: many small independent model evaluations, one per
//! transaction, with model agility (three model families served at once).
//!
//! Loads the AOT artifacts (JAX serving graphs → HLO text), starts the
//! coordinator with **two engine shards** (router + continuous batcher
//! over a bucket ladder of compiled plans, both shards drawing GEMM workers
//! from the one process-wide device pool), fires a mixed workload from
//! concurrent client threads, and reports throughput + latency
//! percentiles + batch occupancy.
//!
//! Run: `cargo run --release --example serve_analytics`
//! (the embedded artifact set is materialized automatically)

use power_mma::coordinator::{Coordinator, CoordinatorConfig, MlpWeights, Payload};
use power_mma::runtime::{det_input, Runtime};
use std::sync::Arc;
use std::time::Instant;

fn main() -> power_mma::error::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if power_mma::runtime::artifacts::ensure_artifacts(&dir)? {
        println!("(materialized embedded AOT artifacts into {})", dir.display());
    }
    // two engine shards behind one process-wide device pool: each model
    // family hashes to a sticky shard (plan buffers stay hot), GEMM
    // workers stay within the shared budget
    let cfg = CoordinatorConfig { shards: 2, ..Default::default() };
    let weights = MlpWeights::deterministic(&cfg);
    let dir2 = dir.clone();
    let ladder = cfg.ladder();
    let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
    let coord = Arc::new(Coordinator::start(cfg.clone(), weights, move |shard| {
        let mut rt = Runtime::cpu(&dir2)?;
        let names = rt.load_all()?;
        let buckets = rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
        println!(
            "engine shard {shard}: loaded {names:?} + buckets {buckets:?} on platform {} \
             ({} pool workers)",
            rt.platform(),
            rt.device().threads()
        );
        Ok(rt)
    }));

    // mixed workload: 90% transactions (classify), 8% gemm tiles, 2% conv
    let n_clients = 8;
    let per_client = 500;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let coord = coord.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let mut ok = 0u32;
                let mut pending = Vec::new();
                for i in 0..per_client {
                    let payload = match (c + i) % 50 {
                        0 => Payload::Conv {
                            filters: det_input(8 * 27, i as u64),
                            image: det_input(3 * 18 * 130, c as u64),
                        },
                        1..=4 => Payload::Gemm {
                            model: if i % 2 == 0 { "gemm_f32" } else { "gemm_bf16" }.into(),
                            x: det_input(128 * 128, i as u64),
                            y: det_input(128 * 128, c as u64 + 1),
                        },
                        _ => Payload::Classify { features: det_input(cfg.features, (c * i) as u64) },
                    };
                    pending.push(coord.submit(payload).1);
                    // keep a bounded number of in-flight requests per client
                    if pending.len() >= 64 {
                        for rx in pending.drain(..) {
                            if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
                                ok += 1;
                            }
                        }
                    }
                }
                for rx in pending {
                    if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let ok: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed();
    let total = (n_clients * per_client) as u32;

    let coord = Arc::try_unwrap(coord).ok().expect("all clients done");
    let stats = coord.shutdown();
    println!("\n== serving results ==");
    println!("requests:   {ok}/{total} ok in {dt:.2?} -> {:.0} req/s", f64::from(total) / dt.as_secs_f64());
    println!(
        "latency:    p50 {} us | p95 {} us | p99 {} us | max {} us",
        stats.latency.quantile_us(0.50),
        stats.latency.quantile_us(0.95),
        stats.latency.quantile_us(0.99),
        stats.latency.max_us()
    );
    println!(
        "batching:   {} batches, mean occupancy {:.1} (ladder {:?})",
        stats.batches.get(),
        stats.mean_batch_occupancy(),
        cfg.ladder()
    );
    for b in &stats.buckets {
        println!(
            "  bucket {:3}: {:4} flushes ({} full, {} deadline, {} shutdown), occupancy {:.2}",
            b.bucket,
            b.flushes(),
            b.full.get(),
            b.deadline.get(),
            b.shutdown.get(),
            b.occupancy()
        );
    }
    println!("rejected:   {} (backpressure)", stats.rejected.get());
    assert_eq!(ok, total, "all requests must succeed");
    Ok(())
}
