//! The §V-B convolution pipeline, across all three layers:
//!
//! 1. run the Figure 9 `sconv_kernel_8x27x16` as a simulated MMA
//!    instruction stream and check it against the direct convolution;
//! 2. time it on the POWER10 model;
//! 3. run the *same computation* through the AOT-compiled conv artifact
//!    (`artifacts/conv2d_k3.hlo.txt`) on the native plan backend and
//!    cross-check the two implementations numerically.
//!
//! Run: `cargo run --release --example conv_pipeline`
//! (the embedded artifact set is materialized automatically)

use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::kernels::sconv::{run_sconv_8x27x16, sconv_8x27x16_program, sconv_reference};
use power_mma::runtime::Runtime;
use power_mma::testkit::Rng;

fn main() -> power_mma::error::Result<()> {
    let mut rng = Rng::new(2024);
    let width = 20usize;
    let filters = rng.f32_vec(8 * 27);
    let r = rng.f32_vec(3 * width);
    let g = rng.f32_vec(3 * width);
    let b = rng.f32_vec(3 * width);

    // ---- 1. instruction-level SCONV -------------------------------------
    let got = run_sconv_8x27x16(&filters, &r, &g, &b, width)?;
    let expect = sconv_reference(&filters, &r, &g, &b, width, 16);
    let mut maxerr = 0f32;
    for f in 0..8 {
        for x in 0..16 {
            maxerr = maxerr.max((got[f][x] - expect[f][x]).abs());
        }
    }
    println!("SCONV 8x27x16 kernel vs direct convolution: max |err| = {maxerr:.2e}");
    assert!(maxerr < 1e-4);

    // ---- 2. POWER10 timing ----------------------------------------------
    let prog = sconv_8x27x16_program((width * 4) as i32);
    let mut sim = CoreSim::new(MachineConfig::power10());
    // channel bases far apart so the cache model sees three streams
    sim.gpr[3] = 0;
    sim.gpr[6] = 4096;
    sim.gpr[7] = 8192;
    sim.gpr[8] = 12288;
    sim.gpr[10] = 16384;
    let rep = sim.run(&prog, 1 << 20);
    println!(
        "POWER10-MMA timing: {} insts in {} cycles -> {:.2} fp32 flops/cycle \
         (fp32 MMA peak = 64)",
        rep.instructions,
        rep.cycles,
        rep.flops_per_cycle()
    );

    // ---- 3. the AOT conv artifact through the native plan backend ----
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if power_mma::runtime::artifacts::ensure_artifacts(&dir)? {
        println!("(materialized embedded AOT artifacts into {})", dir.display());
    }
    let mut rt = Runtime::cpu(&dir)?;
    rt.load("conv2d_k3")?;
    let meta = rt.meta("conv2d_k3").unwrap().clone();
    let (rows, w) = (meta.input_shapes[1][1], meta.input_shapes[1][2]);
    // build an image whose first rows/cols embed the same RGB data
    let mut img = vec![0f32; 3 * rows * w];
    for (c, chan) in [&r, &g, &b].iter().enumerate() {
        for row in 0..3 {
            for x in 0..width {
                img[c * rows * w + row * w + x] = chan[row * width + x];
            }
        }
    }
    // H layout of the Pallas kernel: (8, 27) with taps 9c+3ky+kx — same
    // as the rust kernel's filter layout
    let out = rt.execute("conv2d_k3", &[&filters, &img])?;
    let w_out = w - 2;
    let mut maxerr2 = 0f32;
    for f in 0..8 {
        for x in 0..16 {
            let aot = out[f * (rows - 2) * w_out + x];
            maxerr2 = maxerr2.max((aot - expect[f][x]).abs());
        }
    }
    println!(
        "AOT conv artifact (native plan backend) vs simulated MMA kernel: \
         max |err| = {maxerr2:.2e} (two independent implementations of §V-B)"
    );
    assert!(maxerr2 < 1e-3);
    println!("conv pipeline OK: ISA simulator == direct conv == AOT conv artifact");
    Ok(())
}
