// §Perf probe: wall-clock of the functional interpreter per ger kind and
// of the timing simulator. (temporary tool, not part of the release API)
use power_mma::benchkit::bench;
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::isa::inst::{AccOp, Ger, GerKind, Inst};
use power_mma::isa::Machine;
use power_mma::kernels::dgemm::dgemm_8xnx8_program;

fn ger_loop(kind: GerKind, iters: i32) -> Vec<Inst> {
    let mut prog = vec![Inst::Addi { rt: 9, ra: 0, si: iters }, Inst::Mtctr { rs: 9 }];
    for a in 0..8u8 {
        let xa = if kind == GerKind::F64Ger { 32 + 2 * a } else { 32 + a };
        prog.push(Inst::Ger(Ger::new(kind, AccOp::New, a, xa, 56 + (a % 8))));
    }
    prog.push(Inst::Bdnz { bd: -32 });
    prog.push(Inst::Blr);
    prog
}

fn main() {
    for kind in GerKind::ALL {
        let prog = ger_loop(kind, 4000);
        let mut m = Machine::new(64);
        let s = bench(&format!("{:?}", kind), 1, 9, || {
            m.run(&prog, 1 << 22).unwrap();
        });
        let insts = 4000.0 * 9.0 + 3.0;
        println!("{:<12} {:>8.1} Minst/s ({:>7.1} M-MACs/s)", kind.mnemonic(),
            insts / s.median.as_secs_f64() / 1e6,
            insts * (kind.flops()/2) as f64 / s.median.as_secs_f64() / 1e6);
    }
    // dgemm kernel functional
    let prog = dgemm_8xnx8_program(128);
    let mut m = Machine::new(1 << 16);
    m.gpr[3] = 32768; m.gpr[4] = 0; m.gpr[5] = 8192;
    let s = bench("dgemm_functional", 1, 20, || {
        m.gpr[3] = 32768; m.gpr[4] = 0; m.gpr[5] = 8192;
        m.run(&prog, 1 << 22).unwrap();
    });
    println!("dgemm kernel functional: {:>8.1} Minst/s", 2231.0 / s.median.as_secs_f64() / 1e6);
    // CoreSim
    let mut sim = CoreSim::new(MachineConfig::power10());
    let s = bench("coresim", 1, 20, || { sim.run(&prog, 1 << 22); });
    println!("coresim timing:          {:>8.1} Minst/s", 2231.0 / s.median.as_secs_f64() / 1e6);
}
