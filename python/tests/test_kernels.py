"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
``ref.py`` — the core correctness signal of the compile path.
"""

import pytest

pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="jax/pallas not installed; kernel tests skip")
pytest.importorskip("hypothesis", reason="hypothesis not installed; kernel tests skip")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.mma_conv import mma_conv3x3
from compile.kernels.mma_gemm import (
    mma_gemm,
    mma_gemm_bf16,
    vmem_footprint_bytes,
)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# GEMM kernel
# ---------------------------------------------------------------------------

tiles = st.sampled_from([32, 64])
dims = st.sampled_from([1, 2, 3, 4])


@given(mi=dims, ni=dims, ki=dims, tile=tiles, seed=st.integers(0, 2**31))
def test_gemm_matches_ref(mi, ni, ki, tile, seed):
    m, n, k = mi * tile, ni * tile, ki * tile
    x = rand((m, k), seed)
    y = rand((k, n), seed + 1)
    got = mma_gemm(x, y, tm=tile, tn=tile, tk=tile)
    want = ref.gemm_ref(x, y)
    # f32 accumulation-order differences grow with k; scale atol accordingly
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-6 * k)


@given(seed=st.integers(0, 2**31))
def test_gemm_bf16_matches_bf16_ref(seed):
    x = rand((64, 64), seed)
    y = rand((64, 64), seed + 9)
    got = mma_gemm_bf16(x, y)
    want = ref.gemm_bf16_ref(x, y)
    # identical bf16 rounding on both sides; small f32 summation-order noise
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_bf16_actually_rounds():
    # bf16 path must differ from the f32 path for values needing >8
    # mantissa bits (proves the kernel really computes in bf16)
    x = np.full((32, 32), 1.001, np.float32)
    y = np.eye(32, dtype=np.float32)
    exact = mma_gemm(x, y)
    rounded = mma_gemm_bf16(x, y)
    assert not np.allclose(np.asarray(exact), np.asarray(rounded), rtol=0, atol=1e-6)


def test_gemm_rejects_non_tile_multiple():
    with pytest.raises(AssertionError):
        mma_gemm(np.zeros((33, 32), np.float32), np.zeros((32, 32), np.float32))
    with pytest.raises(AssertionError):
        mma_gemm(np.zeros((32, 31), np.float32), np.zeros((32, 32), np.float32))


def test_gemm_accumulator_resident_across_k():
    # k == 4 tiles: the accumulator must carry partial sums across grid
    # steps (catching a kernel that re-primes per step)
    k = 128
    x = np.ones((32, k), np.float32)
    y = np.ones((k, 32), np.float32)
    got = np.asarray(mma_gemm(x, y))
    assert np.all(got == k), f"expected all {k}, got range [{got.min()}, {got.max()}]"


def test_vmem_footprint_estimate():
    # the §Perf block-shape table: footprint must scale as expected and
    # stay within a 16 MiB VMEM budget for the default tiles
    base = vmem_footprint_bytes(32, 32, 32)
    assert base == 2 * (32 * 32 + 32 * 32) * 4 + 32 * 32 * 4
    assert vmem_footprint_bytes(128, 128, 128) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# Conv kernel
# ---------------------------------------------------------------------------


@given(
    rows=st.integers(3, 10),
    width=st.sampled_from([8, 16, 33, 130]),
    seed=st.integers(0, 2**31),
)
def test_conv_matches_direct(rows, width, seed):
    h = rand((8, 27), seed)
    img = rand((3, rows, width), seed + 3)
    got = mma_conv3x3(h, img)
    want = ref.conv3x3_ref(h, img)
    assert got.shape == (8, rows - 2, width - 2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv_identity_filter():
    h = np.zeros((8, 27), np.float32)
    h[0, 9 * 0 + 3 * 1 + 1] = 1.0  # filter 0 = center tap of channel 0
    img = rand((3, 6, 12), 5)
    out = np.asarray(mma_conv3x3(h, img))
    np.testing.assert_allclose(out[0], img[0, 1:-1, 1:-1], rtol=1e-6)
    assert np.all(out[1:] == 0)


def test_conv_linearity():
    # conv(a*h) == a*conv(h) — catches accumulator contamination
    h = rand((8, 27), 11)
    img = rand((3, 5, 9), 12)
    out1 = np.asarray(mma_conv3x3(h, img))
    out2 = np.asarray(mma_conv3x3(2.0 * h, img))
    np.testing.assert_allclose(out2, 2.0 * out1, rtol=1e-5, atol=1e-6)
