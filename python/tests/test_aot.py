"""The AOT artifact contract: manifest/meta format, expected-output
fixtures, and the determinism the rust runtime relies on."""

import os

import pytest

pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="jax/pallas not installed; AOT tests skip")

import numpy as np

from compile import model
from compile.aot import build_artifact, det_input, shape_str


def test_shape_str():
    assert shape_str((128, 128)) == "128x128"
    assert shape_str((32,)) == "32"


def test_det_input_is_deterministic_and_salt_sensitive():
    a = det_input((8, 8), 1)
    b = det_input((8, 8), 1)
    np.testing.assert_array_equal(a, b)
    c = det_input((8, 8), 2)
    assert not np.array_equal(a, c)
    # values live in [-0.5, 0.5)
    assert a.min() >= -0.5 and a.max() < 0.5
    assert a.dtype == np.float32


def test_build_artifact_round_trip(tmp_path):
    g = 128
    meta = build_artifact("gemm_f32", model.gemm_f32, [(g, g), (g, g)], str(tmp_path))
    assert meta == f"gemm_f32;{g}x{g},{g}x{g};{g}x{g}\n"
    hlo = (tmp_path / "gemm_f32.hlo.txt").read_text()
    assert hlo.startswith("HloModule")
    expected = np.frombuffer((tmp_path / "gemm_f32.expected.bin").read_bytes(), np.float32)
    assert expected.shape == (g * g,)
    # the fixture must equal a recomputation of the model on det inputs
    x = det_input((g, g), 1)
    y = det_input((g, g), 2)
    (out,) = model.gemm_f32(x, y)
    np.testing.assert_allclose(expected.reshape(g, g), np.asarray(out), rtol=1e-6, atol=1e-6)


def test_artifacts_dir_is_consistent_if_built():
    """If the artifact dir was built (`python3 -m compile.aot` or
    `power-mma gen-artifacts`), every manifest entry must have its three
    files and self-consistent sizes."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        return  # not built yet; artifacts/ is generated on demand
    for line in open(manifest):
        if not line.strip():
            continue
        name, ins, out = line.strip().split(";")
        assert os.path.exists(os.path.join(art, f"{name}.hlo.txt")), name
        out_elems = int(np.prod([int(d) for d in out.split("x")]))
        exp = os.path.getsize(os.path.join(art, f"{name}.expected.bin"))
        assert exp == 4 * out_elems, f"{name}: expected.bin size {exp} != 4*{out_elems}"
