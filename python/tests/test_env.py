"""Environment smoke tests that run everywhere — pure stdlib, so ``pytest``
always collects *something* (exit code 0, not 5) even on machines without
JAX/Pallas, where the heavier modules skip themselves via importorskip."""

import importlib.util
import sys


def test_python_version_supported():
    assert sys.version_info >= (3, 9), "compile path targets python >= 3.9"


def test_compile_package_importable_without_jax():
    # the *package* must resolve from the conftest sys.path entry; actually
    # importing compile.model requires jax, which is optional here
    assert importlib.util.find_spec("compile") is not None


def test_optional_deps_report():
    # informational: never fails, documents what the environment provides
    for mod in ("jax", "numpy", "hypothesis"):
        present = importlib.util.find_spec(mod) is not None
        print(f"{mod}: {'present' if present else 'MISSING (dependent tests skip)'}")
