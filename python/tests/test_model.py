"""Layer-2 model shape/numeric checks plus the AOT artifact contract."""

import pytest

pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="jax/pallas not installed; model tests skip")
pytest.importorskip("hypothesis", reason="hypothesis not installed; model tests skip")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.aot import det_input, to_hlo_text
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


def test_gemm_models_match_ref():
    g = model.GEMM_DIM
    x, y = rand((g, g), 1), rand((g, g), 2)
    (out,) = model.gemm_f32(x, y)
    np.testing.assert_allclose(out, ref.gemm_ref(x, y), rtol=1e-5, atol=1e-4)
    (outb,) = model.gemm_bf16(x, y)
    np.testing.assert_allclose(outb, ref.gemm_bf16_ref(x, y), rtol=1e-5, atol=5e-4)


def test_conv_model_shape_and_values():
    h = rand((8, 27), 3)
    img = rand(model.CONV_IMG, 4)
    (out,) = model.conv2d_k3(h, img)
    assert out.shape == (8, model.CONV_IMG[1] - 2, model.CONV_IMG[2] - 2)
    np.testing.assert_allclose(out, ref.conv3x3_ref(h, img), rtol=1e-4, atol=1e-5)


@given(batch=st.sampled_from(model.MLP_BATCHES), seed=st.integers(0, 2**31))
def test_mlp_matches_ref(batch, seed):
    x = rand((batch, model.MLP_FEATURES), seed)
    w1 = rand((model.MLP_FEATURES, model.MLP_HIDDEN), seed + 1) * 0.1
    b1 = rand((model.MLP_HIDDEN,), seed + 2) * 0.1
    w2 = rand((model.MLP_HIDDEN, model.MLP_CLASSES), seed + 3) * 0.1
    b2 = rand((model.MLP_CLASSES,), seed + 4) * 0.1
    (got,) = model.mlp_classifier(x, w1, b1, w2, b2)
    want = ref.mlp_ref(x, w1, b1, w2, b2)
    assert got.shape == (batch, model.MLP_CLASSES)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_det_input_formula_documented_for_rust():
    # the exact values the rust runtime tests regenerate
    v = det_input((4,), salt=1)
    expect = ((np.arange(4) * 31.0 + 7.0) % 61.0) / 61.0 - 0.5
    np.testing.assert_array_equal(v, expect.astype(np.float32))


def test_models_lower_to_hlo_text():
    # the AOT contract: models must lower to parseable HLO text with one
    # tuple-wrapped output (what HloModuleProto::from_text_file expects)
    import jax

    g = model.GEMM_DIM
    spec = jax.ShapeDtypeStruct((g, g), jnp.float32)
    hlo = to_hlo_text(jax.jit(model.gemm_f32).lower(spec, spec))
    assert "HloModule" in hlo
    assert "f32[128,128]" in hlo
