"""AOT compile path: lower every Layer-2 model to **HLO text** artifacts
the rust runtime loads and executes with its native HLO interpreter
(`rust/src/runtime/hlo.rs`).

HLO *text* (not ``.serialize()``) is the interchange format: it is a
stable, human-auditable grammar the rust side parses directly, with no
FFI and no proto toolchain.  The lowered graphs are the jnp-only serving
twins from ``model.py`` — the Pallas kernels are the accelerator-target
path (and lower, in interpret mode, to the whole grid-interpreter loop),
while the serving twins lower to the closed op set the rust interpreter
executes: dot / add / multiply / maximum / broadcast / reshape / slice /
convert / constant / tuple.

For every artifact this also writes
  * ``<name>.meta``         — `name;in0shape,in1shape,…;outshape` (shapes as
    `AxB` strings, f32 unless suffixed) — consumed by the rust runtime;
  * ``<name>.expected.bin`` — f32 little-endian output bytes for the
    deterministic test inputs of :func:`det_input`, giving the rust side an
    end-to-end numeric ground truth it can check without python.

Run once via ``python3 -m compile.aot`` (the checked-in fixture set under
``rust/fixtures`` is regenerated with ``--out-dir ../rust/fixtures``);
never on the request path.  Without a python stack, the rust side
materializes the embedded copies via ``power-mma gen-artifacts``.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # `short_parsable` is byte-identical to `as_hlo_text()`, but exposes
    # `print_large_constants`: without it the printer elides any literal
    # over 16 elements as `constant({...})`, which the rust parser can't
    # execute — the DFT family bakes its 16x16 twiddle matrices into the
    # graph as constants and needs the real values in the text.
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.as_hlo_module().to_string(opts)


def det_input(shape, salt: int) -> np.ndarray:
    """Deterministic pseudo-input, reproduced bit-identically by
    `runtime::det_input` on the rust side: value(i) = ((i*31 + 7*salt) %
    61) / 61 - 0.5, computed in f64, cast to f32."""
    n = int(np.prod(shape))
    idx = np.arange(n, dtype=np.float64)
    vals = ((idx * 31.0 + 7.0 * salt) % 61.0) / 61.0 - 0.5
    return vals.astype(np.float32).reshape(shape)


def shape_str(shape) -> str:
    return "x".join(str(d) for d in shape)


def build_artifact(name, fn, input_shapes, out_dir):
    """Lower `fn` for the given input shapes, run it once on the
    deterministic inputs, and write hlo/meta/expected files."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in input_shapes]
    lowered = jax.jit(fn).lower(*specs)
    hlo = to_hlo_text(lowered)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)

    inputs = [det_input(s, salt + 1) for salt, s in enumerate(input_shapes)]
    outs = fn(*[jnp.asarray(v) for v in inputs])
    # multi-output graphs (the DFT family's (yr, yi) pair) stack their
    # outputs along axis 0 — the same root-order concatenation the rust
    # runtime performs, so `.meta`/`.expected.bin` describe one tensor
    out = np.concatenate([np.asarray(o, dtype=np.float32) for o in outs], axis=0)
    with open(os.path.join(out_dir, f"{name}.expected.bin"), "wb") as f:
        f.write(out.tobytes())
    meta = f"{name};{','.join(shape_str(s) for s in input_shapes)};{shape_str(out.shape)}\n"
    with open(os.path.join(out_dir, f"{name}.meta"), "w") as f:
        f.write(meta)
    print(f"  {name}: {len(hlo)} chars, out {out.shape}")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    g = model.GEMM_DIM
    manifest = []
    print("lowering serving graphs to HLO text:")
    manifest.append(
        build_artifact("gemm_f32", model.gemm_f32_serving, [(g, g), (g, g)], args.out_dir)
    )
    manifest.append(
        build_artifact("gemm_bf16", model.gemm_bf16_serving, [(g, g), (g, g)], args.out_dir)
    )
    manifest.append(
        build_artifact(
            "conv2d_k3", model.conv2d_k3_serving, [(8, 27), model.CONV_IMG], args.out_dir
        )
    )
    for b in model.MLP_BATCHES:
        manifest.append(
            build_artifact(
                f"mlp_b{b}",
                model.mlp_classifier_serving,
                [
                    (b, model.MLP_FEATURES),
                    (model.MLP_FEATURES, model.MLP_HIDDEN),
                    (model.MLP_HIDDEN,),
                    (model.MLP_HIDDEN, model.MLP_CLASSES),
                    (model.MLP_CLASSES,),
                ],
                args.out_dir,
            )
        )
    for b in model.DFT_BATCHES:
        manifest.append(
            build_artifact(
                f"dft_b{b}",
                model.dft16_serving,
                [(b, model.DFT_N), (b, model.DFT_N)],
                args.out_dir,
            )
        )
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.writelines(manifest)
    print(f"wrote {len(manifest)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
