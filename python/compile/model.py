"""Layer-2 JAX models: the compute graphs the rust coordinator serves,
built on the Layer-1 Pallas kernels.

* :func:`gemm_f32` / :func:`gemm_bf16` — the §V-A matrix-multiply service
  (the kernels the paper contributes to OpenBLAS/Eigen).
* :func:`conv2d_k3` — the §V-B multi-filter 3×3 convolution.
* :func:`mlp_classifier` — the §I "data-in-flight business analytics"
  model: a small tabular classifier whose matmuls run through the MMA-style
  GEMM kernel; the coordinator batches transactions through it.

These functions are *build-time only*: ``aot.py`` lowers them to HLO text
once; the rust runtime loads and executes the artifacts. Python never sits
on the request path.
"""

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.mma_conv import mma_conv3x3
from compile.kernels.mma_gemm import mma_gemm, mma_gemm_bf16

# Model dimensions (fixed at AOT time; multiples of the kernel tiles).
GEMM_DIM = 128
MLP_FEATURES = 64
MLP_HIDDEN = 128
MLP_CLASSES = 32
MLP_BATCHES = (32,)  # compiled batch size(s); the batcher pads to these
CONV_IMG = (3, 18, 130)  # (channels, rows, width) -> (8, 16, 128) output


def gemm_f32(x, y):
    """`C = X·Y`, 128³, f32 — one paper DGEMM-kernel-sized tile."""
    return (mma_gemm(x, y),)


def gemm_bf16(x, y):
    """bf16 inputs, f32 accumulation (the `xvbf16ger2` service)."""
    return (mma_gemm_bf16(x, y),)


def conv2d_k3(h, img):
    """8-filter 3-channel 3×3 valid convolution (§V-B)."""
    return (mma_conv3x3(h, img),)


def mlp_classifier(x, w1, b1, w2, b2):
    """relu(x·W1 + b1)·W2 + b2 — both matmuls through the Pallas kernel.

    `x` is `(batch, 64)`; weights are padded to tile multiples at AOT time.
    Returns logits `(batch, 32)`.
    """
    batch = x.shape[0]
    # pad the batch to a tile multiple; the kernel tiles are 32-aligned
    tile = 32
    pad = (-batch) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    h = mma_gemm(x, w1, tm=tile, tn=32, tk=32) + b1
    h = jnp.maximum(h, 0.0)
    out = mma_gemm(h, w2, tm=tile, tn=32, tk=32) + b2
    return (out[:batch],)


# ---------------------------------------------------------------------------
# Serving graphs — what `aot.py` actually lowers to the HLO artifacts.
#
# The Pallas kernels above are the accelerator-target path; on CPU they run
# in interpret mode, and interpret mode *lowers* to the whole Pallas grid
# interpreter (HLO while-loops, dynamic slices, selects).  The rust runtime
# executes artifacts with a native HLO interpreter over a closed op set
# (dot / add / multiply / maximum / broadcast / reshape / slice / convert /
# constant / tuple), so the artifacts are lowered from the pure-jnp twins
# below instead.  They are numerically the same graphs: pytest asserts the
# Pallas kernels match `ref.py`, and `ref.py` is exactly what these twins
# compute.
# ---------------------------------------------------------------------------


def gemm_f32_serving(x, y):
    """jnp-only twin of :func:`gemm_f32` for the AOT serving artifact."""
    return (ref.gemm_ref(x, y),)


def gemm_bf16_serving(x, y):
    """jnp-only twin of :func:`gemm_bf16` (bf16 rounding via `convert`)."""
    return (ref.gemm_bf16_ref(x, y),)


def conv2d_k3_serving(h, img):
    """jnp-only twin of :func:`conv2d_k3` (27 shifted rank-1 updates)."""
    return (ref.conv3x3_ref(h, img),)


def mlp_classifier_serving(x, w1, b1, w2, b2):
    """jnp-only twin of :func:`mlp_classifier` (batch already padded)."""
    return (ref.mlp_ref(x, w1, b1, w2, b2),)
