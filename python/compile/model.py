"""Layer-2 JAX models: the compute graphs the rust coordinator serves,
built on the Layer-1 Pallas kernels.

* :func:`gemm_f32` / :func:`gemm_bf16` — the §V-A matrix-multiply service
  (the kernels the paper contributes to OpenBLAS/Eigen).
* :func:`conv2d_k3` — the §V-B multi-filter 3×3 convolution.
* :func:`mlp_classifier` — the §I "data-in-flight business analytics"
  model: a small tabular classifier whose matmuls run through the MMA-style
  GEMM kernel; the coordinator batches transactions through it.

These functions are *build-time only*: ``aot.py`` lowers them to HLO text
once; the rust runtime loads and executes the artifacts. Python never sits
on the request path.
"""

import math

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.mma_conv import mma_conv3x3
from compile.kernels.mma_gemm import mma_gemm, mma_gemm_bf16

# Model dimensions (fixed at AOT time; multiples of the kernel tiles).
GEMM_DIM = 128
MLP_FEATURES = 64
MLP_HIDDEN = 128
MLP_CLASSES = 32
MLP_BATCHES = (32,)  # compiled batch size(s); the batcher pads to these
CONV_IMG = (3, 18, 130)  # (channels, rows, width) -> (8, 16, 128) output
DFT_N = 16  # DFT length (one request row = one 16-point transform)
DFT_BATCHES = (32,)  # compiled batch size(s) for the DFT family


def gemm_f32(x, y):
    """`C = X·Y`, 128³, f32 — one paper DGEMM-kernel-sized tile."""
    return (mma_gemm(x, y),)


def gemm_bf16(x, y):
    """bf16 inputs, f32 accumulation (the `xvbf16ger2` service)."""
    return (mma_gemm_bf16(x, y),)


def conv2d_k3(h, img):
    """8-filter 3-channel 3×3 valid convolution (§V-B)."""
    return (mma_conv3x3(h, img),)


def mlp_classifier(x, w1, b1, w2, b2):
    """relu(x·W1 + b1)·W2 + b2 — both matmuls through the Pallas kernel.

    `x` is `(batch, 64)`; weights are padded to tile multiples at AOT time.
    Returns logits `(batch, 32)`.
    """
    batch = x.shape[0]
    # pad the batch to a tile multiple; the kernel tiles are 32-aligned
    tile = 32
    pad = (-batch) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    h = mma_gemm(x, w1, tm=tile, tn=32, tk=32) + b1
    h = jnp.maximum(h, 0.0)
    out = mma_gemm(h, w2, tm=tile, tn=32, tk=32) + b2
    return (out[:batch],)


# ---------------------------------------------------------------------------
# Serving graphs — what `aot.py` actually lowers to the HLO artifacts.
#
# The Pallas kernels above are the accelerator-target path; on CPU they run
# in interpret mode, and interpret mode *lowers* to the whole Pallas grid
# interpreter (HLO while-loops, dynamic slices, selects).  The rust runtime
# executes artifacts with a native HLO interpreter over a closed op set
# (dot / add / multiply / maximum / broadcast / reshape / slice / convert /
# constant / tuple), so the artifacts are lowered from the pure-jnp twins
# below instead.  They are numerically the same graphs: pytest asserts the
# Pallas kernels match `ref.py`, and `ref.py` is exactly what these twins
# compute.
# ---------------------------------------------------------------------------


def gemm_f32_serving(x, y):
    """jnp-only twin of :func:`gemm_f32` for the AOT serving artifact."""
    return (ref.gemm_ref(x, y),)


def gemm_bf16_serving(x, y):
    """jnp-only twin of :func:`gemm_bf16` (bf16 rounding via `convert`)."""
    return (ref.gemm_bf16_ref(x, y),)


def conv2d_k3_serving(h, img):
    """jnp-only twin of :func:`conv2d_k3` (27 shifted rank-1 updates)."""
    return (ref.conv3x3_ref(h, img),)


def mlp_classifier_serving(x, w1, b1, w2, b2):
    """jnp-only twin of :func:`mlp_classifier` (batch already padded)."""
    return (ref.mlp_ref(x, w1, b1, w2, b2),)


def _dft16_twiddles():
    """``(Fr, Fi)`` as nested row-major lists: ``F[j][k] = exp(-2πi·jk/16)``.

    Built from *exact* IEEE-754 sqrt expressions (sqrt, divide, and
    negate are correctly rounded and exactly specified), so the rust
    generator (`kernels::dft::dft16_twiddles_f32`) computing the same
    formula produces bit-identical f32 values — no libm cos/sin
    divergence between languages, which is what lets the AOT fixture,
    the rust bucket generator, and the fused plan agree byte for byte.
    """
    s2 = math.sqrt(2.0)
    c1 = math.sqrt(2.0 + s2) / 2.0  # cos(pi/8)
    c2 = s2 / 2.0  # cos(pi/4)
    c3 = math.sqrt(2.0 - s2) / 2.0  # cos(3pi/8)
    cos = [1.0, c1, c2, c3, 0.0, -c3, -c2, -c1, -1.0, -c1, -c2, -c3, 0.0, c3, c2, c1]
    sin = [0.0, c3, c2, c1, 1.0, c1, c2, c3, 0.0, -c3, -c2, -c1, -1.0, -c1, -c2, -c3]
    n = DFT_N
    fr = [[cos[(j * k) % n] for k in range(n)] for j in range(n)]
    fi = [[-sin[(j * k) % n] for k in range(n)] for j in range(n)]
    return fr, fi


def dft16_serving(xr, xi):
    """Real-signal batched 16-point DFT as a complex matmul — the second
    served model family.

    One request row is one transform: ``y[r] = DFT(xr[r] + i·xi[r])``,
    computed against the baked twiddle constants of
    :func:`_dft16_twiddles` as ``yr = xr·Fr − xi·Fi``,
    ``yi = xr·Fi + xi·Fr`` (``F`` is symmetric, so the row-per-request
    layout needs no transpose).  The subtraction is written as
    ``+ (−1)·`` so XLA lowers it to the
    ``multiply(dot, broadcast(constant(-1)))`` then ``add`` shape the
    rust plan compiler's DFT matcher fuses (in either operand order)
    into a single ``dft_gemm`` step over once-packed twiddle panels.  IEEE-754 makes
    ``a + (−1·b)`` bitwise identical to ``a − b``, so the lowering
    costs nothing numerically.
    """
    fr_rows, fi_rows = _dft16_twiddles()
    fr = jnp.asarray(fr_rows, dtype=jnp.float32)
    fi = jnp.asarray(fi_rows, dtype=jnp.float32)
    yr = jnp.dot(xr, fr) + (-1.0) * jnp.dot(xi, fi)
    yi = jnp.dot(xr, fi) + jnp.dot(xi, fr)
    return (yr, yi)
