"""Layer-1 Pallas kernel: tiled GEMM in the **MMA idiom** on the TPU
abstraction (DESIGN.md §Hardware-Adaptation).

The paper's Matrix Math Engine keeps the 512-bit accumulators *inside* the
functional unit for the whole rank-k loop — "the accumulator data stays
local to the matrix math engine. Only the X and Y inputs have to be brought
from the register files" (§III). The TPU mapping:

* the accumulator tile lives in **VMEM scratch** and is written back to HBM
  exactly once, after the last K step (`@pl.when(k == nk-1)`) — the
  `xxmfacc` analogue;
* X/Y panels stream HBM→VMEM under `BlockSpec` control — the fetch buses;
* each grid step performs a rank-`TK` update on the MXU
  (`jnp.dot(..., preferred_element_type=f32)`) — the `xv…ger…pp`
  instructions, including the fp32-accumulate-of-bf16 contract of
  `xvbf16ger2pp`.

Kernels must run with ``interpret=True`` on CPU: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile sizes: multiples of the MXU systolic array (128×128 on real
# TPUs); kept at 32/64 here so small models and tests stay exact multiples.
DEFAULT_TM = 32
DEFAULT_TN = 32
DEFAULT_TK = 32


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk):
    """One grid step: rank-TK update of the VMEM-resident accumulator."""

    @pl.when(pl.program_id(2) == 0)
    def _prime():  # the xxsetaccz analogue: prime the accumulator
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the rank-k update: A += X @ Yᵀ-tile on the MXU, f32 accumulation
    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _deprime():  # the xxmfacc analogue: single write-back to HBM
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def mma_gemm(
    x: jax.Array,
    y: jax.Array,
    *,
    tm: int = DEFAULT_TM,
    tn: int = DEFAULT_TN,
    tk: int = DEFAULT_TK,
    interpret: bool = True,
) -> jax.Array:
    """Tiled ``x @ y`` with an accumulator-resident schedule.

    ``x`` is ``(m, k)``, ``y`` is ``(k, n)``; f32 or bf16 inputs, f32
    output. Dimensions must be multiples of the tile sizes (the residual
    shapes of §II-C are handled architecturally by the rust ISA layer; at
    this level callers pad, as production GEMMs do).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % tm == 0 and n % tn == 0 and k % tk == 0, (
        f"shape ({m},{n},{k}) not a multiple of tiles ({tm},{tn},{tk})"
    )
    nk = k // tk
    return pl.pallas_call(
        partial(_gemm_kernel, nk=nk),
        grid=(m // tm, n // tn, nk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def mma_gemm_bf16(x: jax.Array, y: jax.Array, **kw) -> jax.Array:
    """bf16 inputs, f32 accumulation — the `xvbf16ger2` contract."""
    return mma_gemm(x.astype(jnp.bfloat16), y.astype(jnp.bfloat16), **kw)


def vmem_footprint_bytes(tm: int, tn: int, tk: int, in_dtype=jnp.float32) -> int:
    """Estimated VMEM residency of one grid step: X tile + Y tile (double
    buffered) + f32 accumulator. Used by the L1 perf notes in
    EXPERIMENTS.md §Perf (interpret mode gives no real timings)."""
    esz = jnp.dtype(in_dtype).itemsize
    return 2 * (tm * tk + tk * tn) * esz + tm * tn * 4


def mxu_utilization_estimate(tm: int, tn: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes a (tm, tn) output tile keeps busy — the
    roofline proxy for real-TPU execution (interpret mode gives no
    hardware timing)."""
    return min(tm / mxu, 1.0) * min(tn / mxu, 1.0)
