"""Pure-jnp oracles for the Pallas kernels — the correctness contract
checked by pytest at build time (and by hypothesis sweeps in
``python/tests``)."""

import jax.numpy as jnp


def gemm_ref(x, y):
    """f32 GEMM reference."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def gemm_bf16_ref(x, y):
    """bf16-inputs / f32-accumulate reference (the `xvbf16ger2` contract:
    inputs rounded to bf16, products and sums in f32)."""
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    yb = y.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.dot(xb, yb)


def conv3x3_ref(h, img):
    """Direct valid 3×3 × 3-channel convolution; ``h`` is ``(8, 27)`` with
    taps ordered ``9*c + 3*ky + kx``; ``img`` is ``(3, rows, width)``."""
    img = img.astype(jnp.float32)
    _, rows, width = img.shape
    out = jnp.zeros((h.shape[0], rows - 2, width - 2), jnp.float32)
    for c in range(3):
        for ky in range(3):
            for kx in range(3):
                tap = h[:, 9 * c + 3 * ky + kx][:, None, None]
                patch = img[c, ky : ky + rows - 2, kx : kx + width - 2][None, :, :]
                out = out + tap * patch
    return out


def mlp_ref(x, w1, b1, w2, b2):
    """Two-layer MLP reference (f32 throughout)."""
    hline = jnp.maximum(jnp.dot(x, w1) + b1, 0.0)
    return jnp.dot(hline, w2) + b2
