"""Layer-1 Pallas kernel: the SCONV schedule (paper §V-B) on TPU.

The paper's insight: with fine-grain outer-product instructions, a 3×3
multi-channel convolution runs **directly on the image** — the `H̄` filter
matrix (8×27) is the left operand and each image row is used three times at
shifts 0/+1/+2 (equation 8) — no im2col materialization of the 9×(m−2)
matrix.

TPU mapping: one grid step owns one output row. The three input rows it
needs arrive as three row-shifted views of the image (the `R`, `R+n`,
`R+2n` pointers of Figure 9), each streamed HBM→VMEM by its `BlockSpec`;
the kernel performs the 27 shifted rank-1 outer-product accumulations
against a resident accumulator — exactly the Figure 9 step sequence.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NUM_FILTERS = 8
TAPS = 27  # 3 channels x 3x3 kernel


def _conv_kernel(h_ref, r0_ref, r1_ref, r2_ref, o_ref, *, w_out):
    """One output row: 27 shifted rank-1 updates (Figure 9's 27
    `mma_xvf32_8x16` steps, generalized to a full row)."""
    h = h_ref[...]  # (8, 27)
    rows = (r0_ref[...], r1_ref[...], r2_ref[...])  # each (3, 1, w)
    acc = jnp.zeros((NUM_FILTERS, w_out), jnp.float32)
    for c in range(3):
        for ky in range(3):
            for kx in range(3):
                tap = h[:, 9 * c + 3 * ky + kx][:, None]  # H̄ column (8x1)
                row = rows[ky][c, 0, kx : kx + w_out][None, :]  # shifted row
                acc = acc + tap * row  # rank-1 outer product, acc resident
    o_ref[...] = acc[:, None, :]


def mma_conv3x3(h: jax.Array, img: jax.Array, *, interpret: bool = True) -> jax.Array:
    """``h`` is ``(8, 27)`` (filter × channel-major taps); ``img`` is
    ``(3, rows, width)``. Returns ``(8, rows-2, width-2)`` — valid
    convolution, single stepping (the §V-B setting)."""
    _, taps = h.shape
    assert taps == TAPS
    chans, rows, width = img.shape
    assert chans == 3 and rows >= 3 and width >= 3
    out_rows = rows - 2
    w_out = width - 2
    img = img.astype(jnp.float32)
    # the three row-shifted views of eq. (8): ky = 0, 1, 2
    shifted = [img[:, ky : ky + out_rows, :] for ky in range(3)]
    row_spec = pl.BlockSpec((3, 1, width), lambda r: (0, r, 0))
    return pl.pallas_call(
        partial(_conv_kernel, w_out=w_out),
        grid=(out_rows,),
        in_specs=[
            pl.BlockSpec((NUM_FILTERS, TAPS), lambda r: (0, 0)),
            row_spec,
            row_spec,
            row_spec,
        ],
        out_specs=pl.BlockSpec((NUM_FILTERS, 1, w_out), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((NUM_FILTERS, out_rows, w_out), jnp.float32),
        interpret=interpret,
    )(h, *shifted)
